"""RAS / fault-injection layer (ARCHITECTURE §10) — the property
harness that locks the fault model down.

Everything is stated against the request-at-a-time spec
(:func:`repro.core.timing.simulate_faults_seq`) or against the
fault-free simulators the RAS layer must degenerate to:

* scalar and vectorized hash draws are the same wrapping arithmetic,
  bit for bit;
* the same (seed, channel) reproduces the same storm — determinism;
* fast path == oracle under full storms (every count, stamp, attempt
  and FaultStats field), over BER x ECC x replay x degradation knobs;
* an inactive config is *bit-identical* to the pre-RAS world: the
  sequential oracle against ``simulate_arrivals_seq``, and the full
  pipeline against the checked-in golden records (schema included);
* replay is bounded: attempts <= max_replays + 1, and a request either
  completes or is flagged dropped — never silently lost;
* a retired row never serves again: after retirement every later
  access to the natural row issues against its spare;
* outage windows stall but drop nothing; failed-channel remap keeps
  the AddressMap a bijection and the dead channel empty.
"""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import faults as F
from repro.core.config import (ChannelConfig, DRAMSchedConfig, FaultConfig,
                               MemoryControllerConfig)
from repro.core.controller import MemoryController
from repro.core.faults import SPARE_ROW_BASE, FaultStats
from repro.core.timing import (DDR4_2400, simulate_arrivals_seq,
                               simulate_faults, simulate_faults_seq)
from repro.core.trace_engine import simulate_faults_fast

STORM = FaultConfig(seed=2, transient_ber=0.01, weak_row_fraction=0.02,
                    weak_row_ber=0.5, due_fraction=0.3, max_replays=3,
                    backoff_clocks=64, row_retire_threshold=2,
                    refresh_escalate_threshold=25,
                    outage_windows=((0, 2000, 5000),))


def _trace(seed, n=1500, n_rows=600, ports=3, rate=0.08):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n_rows, n).astype(np.int64)
    addrs = rows * DDR4_2400.row_bytes
    rw = (rng.random(n) < 0.3).astype(np.int32)
    pe = rng.integers(0, ports, n).astype(np.int64)
    arr = np.cumsum(-np.log(np.clip(rng.random(n), 1e-12, 1.0)) / rate)
    return addrs, rw, pe, arr


def _assert_results_equal(a, b):
    assert a.total_fpga_cycles == b.total_fpga_cycles
    assert (a.row_hits, a.row_conflicts, a.first_accesses) == \
        (b.row_hits, b.row_conflicts, b.first_accesses)
    assert (a.n_refreshes, a.turnaround_dram_cycles) == \
        (b.n_refreshes, b.turnaround_dram_cycles)
    assert a.idle_dram_cycles == b.idle_dram_cycles
    np.testing.assert_array_equal(a.service_order, b.service_order)
    np.testing.assert_array_equal(a.grant_order, b.grant_order)
    np.testing.assert_array_equal(a.completion_fpga_cycles,
                                  b.completion_fpga_cycles)
    np.testing.assert_array_equal(a.service_dram_cycles,
                                  b.service_dram_cycles)


def _assert_fault_results_equal(a, b):
    _assert_results_equal(a, b)
    np.testing.assert_array_equal(a.attempts, b.attempts)
    np.testing.assert_array_equal(a.dropped, b.dropped)
    assert a.fault.as_dict() == b.fault.as_dict()


# ---------------------------------------------------------------------------
# The hash: scalar spec == vectorized, and it is deterministic
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**32), st.integers(0, 7),
       st.lists(st.integers(0, 2**40), min_size=1, max_size=50),
       st.integers(1, 5))
@settings(max_examples=50, deadline=None)
def test_property_scalar_and_vector_draws_identical(seed, ch, idxs, att):
    fc = FaultConfig(seed=seed, transient_ber=0.5, weak_row_fraction=0.5,
                     weak_row_ber=0.1)
    idx = np.asarray(idxs, np.int64)
    vec = F.error_uniforms(fc, ch, idx, att)
    for k, i in enumerate(idxs):
        assert vec[k] == F.error_uniform(fc, ch, i, att)
    wvec = F.weak_rows(fc, ch, idx)
    for k, i in enumerate(idxs):
        assert wvec[k] == F.weak_row(fc, ch, i)


def test_draws_decorrelate_across_streams():
    """Different channels / attempts / seeds see different storms, and
    every uniform is in [0, 1)."""
    fc = FaultConfig(seed=3, transient_ber=0.5)
    idx = np.arange(4000)
    a = F.error_uniforms(fc, 0, idx, 1)
    assert ((0.0 <= a) & (a < 1.0)).all()
    assert a.mean() == pytest.approx(0.5, abs=0.05)
    for other in (F.error_uniforms(fc, 1, idx, 1),
                  F.error_uniforms(fc, 0, idx, 2),
                  F.error_uniforms(dataclasses.replace(fc, seed=4),
                                   0, idx, 1)):
        assert not np.array_equal(a, other)
    np.testing.assert_array_equal(a, F.error_uniforms(fc, 0, idx, 1))


def test_spare_rows_are_never_weak():
    fc = FaultConfig(weak_row_fraction=1.0, weak_row_ber=1.0)
    assert F.weak_row(fc, 0, 5)
    assert not F.weak_row(fc, 0, SPARE_ROW_BASE + 5)
    flags = F.weak_rows(fc, 0, np.array([5, SPARE_ROW_BASE + 5]))
    np.testing.assert_array_equal(flags, [True, False])


# ---------------------------------------------------------------------------
# Zero-rate degeneracy: inactive faults are bit-identical to no faults
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("faults", [None, FaultConfig(),
                                    FaultConfig(seed=99, max_replays=1)])
def test_inactive_faults_match_arrivals_oracle(faults):
    addrs, rw, pe, arr = _trace(0)
    sched = DRAMSchedConfig(policy="frfcfs_cap", reorder_window=16,
                            starvation_cap=8, t_refi=4000, t_rfc=160)
    base = simulate_arrivals_seq(addrs, DDR4_2400, sched, rw,
                                 arrival_fpga=arr, pe_id=pe, num_ports=3)
    res = simulate_faults_seq(addrs, DDR4_2400, sched, rw, faults=faults,
                              arrival_fpga=arr, pe_id=pe, num_ports=3)
    _assert_results_equal(res, base)
    assert res.attempts.max() == 1 and not res.dropped.any()
    assert res.fault.as_dict() == FaultStats().as_dict()


def test_zero_rate_pipeline_reproduces_existing_goldens():
    """The full pipeline with a zero-rate FaultConfig injected must
    reproduce the *pre-RAS* golden records exactly — every stat, stage
    count and sojourn percentile, and the schema itself (no fault
    block appears)."""
    import golden_cases

    for name in ("serving_poisson_frfcfs", "serving_hog_victim_weighted"):
        cfg, workload, apol, w = golden_cases.SERVING_CASES[name]
        assert cfg.faults is None
        stormless = dataclasses.replace(cfg, faults=FaultConfig(seed=7))
        rows, rw, pe, arr = workload()
        res = MemoryController(stormless).simulate(
            pe, rows, rw, golden_cases.ROW_BYTES, arbiter_policy=apol,
            weights=w, arrival_cycle=arr)
        assert res.fault is None and res.dropped is None
        golden_cases.SERVING_CASES[name] = (stormless, workload, apol, w)
        try:
            got = golden_cases.golden_record(name)
        finally:
            golden_cases.SERVING_CASES[name] = (cfg, workload, apol, w)
        import json
        import os
        with open(os.path.join(golden_cases.GOLDEN_DIR,
                               f"{name}.json")) as f:
            want = json.load(f)
        assert got == want


# ---------------------------------------------------------------------------
# Fast path == oracle under storms
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31), st.floats(0.0, 0.05),
       st.sampled_from(["secded", "none"]), st.booleans(),
       st.integers(0, 4), st.sampled_from([0, 16, 256]),
       st.sampled_from(["fifo", "frfcfs", "frfcfs_cap"]),
       st.booleans(), st.booleans())
@settings(max_examples=25, deadline=None)
def test_property_fast_matches_oracle_under_storm(
        seed, ber, ecc, crc, max_replays, backoff, policy, refresh,
        degrade):
    fc = FaultConfig(seed=seed, transient_ber=ber, weak_row_fraction=0.05,
                     weak_row_ber=0.4, due_fraction=0.35, ecc=ecc,
                     write_crc=crc, max_replays=max_replays,
                     backoff_clocks=backoff,
                     row_retire_threshold=2 if degrade else 0,
                     refresh_escalate_threshold=30 if degrade else 0,
                     outage_windows=((0, 1000, 2500),) if degrade else ())
    addrs, rw, pe, arr = _trace(seed % 17, n=700, ports=2)
    sched = DRAMSchedConfig(
        policy=policy, reorder_window=1 if policy == "fifo" else 16,
        starvation_cap=8, t_refi=4000 if refresh else 0, t_rfc=160)
    kw = dict(rw=rw, faults=fc, arrival_fpga=arr, pe_id=pe, num_ports=2,
              arb_policy="round_robin")
    oracle = simulate_faults_seq(addrs, DDR4_2400, sched, **kw)
    fast = simulate_faults_fast(addrs, DDR4_2400, sched, **kw)
    _assert_fault_results_equal(fast, oracle)


def test_dispatcher_engines_agree_on_storm():
    addrs, rw, pe, arr = _trace(5)
    sched = DRAMSchedConfig(policy="frfcfs", reorder_window=16)
    kw = dict(rw=rw, faults=STORM, arrival_fpga=arr, pe_id=pe,
              num_ports=3, arb_policy="round_robin")
    a = simulate_faults(addrs, DDR4_2400, sched, engine="fast", **kw)
    b = simulate_faults(addrs, DDR4_2400, sched, engine="sequential", **kw)
    _assert_fault_results_equal(a, b)
    assert a.fault.n_injected > 0          # the storm actually landed


# ---------------------------------------------------------------------------
# Replay bounds, drops, and degradation semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("max_replays", [0, 1, 3])
def test_replay_is_bounded_and_drops_are_counted(max_replays):
    """attempts <= max_replays + 1 always; hard-failing weak cells
    (error probability 1, every error DUE/CRC) exhaust the replay
    budget at any bound, and every exhausted request is flagged
    dropped — completion stamped, never lost."""
    fc = FaultConfig(seed=1, transient_ber=0.08, due_fraction=1.0,
                     weak_row_fraction=0.1, weak_row_ber=1.0,
                     max_replays=max_replays, backoff_clocks=8)
    addrs, rw, pe, arr = _trace(2, n=800)
    res = simulate_faults_seq(addrs, DDR4_2400,
                              DRAMSchedConfig(policy="frfcfs",
                                              reorder_window=8),
                              rw, faults=fc, arrival_fpga=arr, pe_id=pe,
                              num_ports=3)
    assert int(res.attempts.max()) <= max_replays + 1
    assert res.fault.n_dropped == int(res.dropped.sum())
    assert res.fault.n_dropped > 0
    assert (res.completion_fpga_cycles > 0).all()      # nothing lost
    assert sum(res.fault.dropped_by_port.values()) == res.fault.n_dropped
    # every issue (replays included) appears in the service order
    counts = np.bincount(res.service_order, minlength=len(addrs))
    np.testing.assert_array_equal(counts, res.attempts)


def test_backoff_defers_replays():
    """With enormous backoff the replays of a failing request land
    later than with immediate retry — backoff trades the failing
    request's latency for bus time near the failure."""
    base = FaultConfig(seed=1, transient_ber=0.05, due_fraction=1.0,
                       max_replays=2, backoff_clocks=0)
    slow = dataclasses.replace(base, backoff_clocks=4096)
    addrs, rw, pe, arr = _trace(3, n=600)
    sched = DRAMSchedConfig(policy="frfcfs", reorder_window=8)
    r0 = simulate_faults_seq(addrs, DDR4_2400, sched, rw, faults=base,
                             arrival_fpga=arr)
    r1 = simulate_faults_seq(addrs, DDR4_2400, sched, rw, faults=slow,
                             arrival_fpga=arr)
    # same storm (same seed/coords), so the same requests err...
    assert r0.fault.n_injected >= 1
    np.testing.assert_array_equal(r0.attempts >= 2, r1.attempts >= 2)
    # ...but the backed-off run finishes its victims strictly later
    errored = r0.attempts >= 2
    assert (r1.completion_fpga_cycles[errored]
            > r0.completion_fpga_cycles[errored]).all()


def test_retired_row_never_serves_again():
    """After (channel, row) appears in rows_retired, every later issue
    to that natural row serves from its spare: re-run the same trace
    with retirement disabled and confirm the retired rows keep
    erroring there, while the retire run's spare issues stop charging
    the natural row (spare_issues > 0 and the retired set is stable
    under a second pass of the same storm)."""
    fc = dataclasses.replace(STORM, row_retire_threshold=2,
                             outage_windows=())
    addrs, rw, pe, arr = _trace(7, n=2500, n_rows=150)
    sched = DRAMSchedConfig(policy="frfcfs", reorder_window=16)
    res = simulate_faults_seq(addrs, DDR4_2400, sched, rw, faults=fc,
                              arrival_fpga=arr)
    assert len(res.fault.rows_retired) > 0
    assert res.fault.spare_issues > 0
    retired_rows = {r for _c, r in res.fault.rows_retired}
    # a row is retired at most once — serving again would re-retire it
    assert len(retired_rows) == len(res.fault.rows_retired)
    # capacity cap respected
    assert len(retired_rows) <= fc.max_retired_rows
    capped = dataclasses.replace(fc, max_retired_rows=1)
    res1 = simulate_faults_seq(addrs, DDR4_2400, sched, rw, faults=capped,
                               arrival_fpga=arr)
    assert len(res1.fault.rows_retired) <= 1


def test_refresh_escalation_fires_and_is_capped():
    fc = FaultConfig(seed=2, transient_ber=0.05,
                     refresh_escalate_threshold=10,
                     refresh_escalate_max=2)
    addrs, rw, pe, arr = _trace(8, n=2000)
    sched = DRAMSchedConfig(policy="frfcfs", reorder_window=16,
                            t_refi=4000, t_rfc=160)
    res = simulate_faults_seq(addrs, DDR4_2400, sched, rw, faults=fc,
                              arrival_fpga=arr)
    base = simulate_arrivals_seq(addrs, DDR4_2400, sched, rw,
                                 arrival_fpga=arr)
    assert 1 <= res.fault.refresh_escalations <= 2
    assert res.n_refreshes > base.n_refreshes   # shorter t_refi_eff


def test_outage_stalls_but_drops_nothing():
    fc = FaultConfig(seed=0, outage_windows=((0, 1000, 21000),))
    addrs, rw, pe, arr = _trace(9, n=500)
    sched = DRAMSchedConfig(policy="frfcfs", reorder_window=8)
    res = simulate_faults_seq(addrs, DDR4_2400, sched, rw, faults=fc,
                              arrival_fpga=arr)
    base = simulate_arrivals_seq(addrs, DDR4_2400, sched, rw,
                                 arrival_fpga=arr)
    assert res.fault.outage_dram_cycles > 0
    assert res.fault.n_dropped == 0 and not res.dropped.any()
    assert res.total_fpga_cycles > base.total_fpga_cycles
    # outage on another channel's windows is invisible to this one
    other = FaultConfig(seed=0, outage_windows=((1, 1000, 21000),))
    res2 = simulate_faults_seq(addrs, DDR4_2400, sched, rw, faults=other,
                               arrival_fpga=arr, channel=0)
    _assert_results_equal(res2, base)


# ---------------------------------------------------------------------------
# Failed channels: AddressMap bijection + pipeline remap
# ---------------------------------------------------------------------------

@given(st.sampled_from(["row_interleave", "block_interleave", "xor"]),
       st.sampled_from([(1,), (0, 2), (3,)]),
       st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_property_failed_channel_map_is_bijective(policy, failed, seed):
    from repro.core.channels import AddressMap

    amap = AddressMap(ChannelConfig(num_channels=4, policy=policy),
                      DDR4_2400, FaultConfig(failed_channels=failed))
    rng = np.random.default_rng(seed)
    addrs = (rng.integers(0, 1 << 30, 400) // 64 * 64).astype(np.int64)
    ch = amap.channel_of(addrs)
    assert not np.isin(ch, list(failed)).any()
    local = amap.local_addr(addrs)
    np.testing.assert_array_equal(amap.global_addr(ch, local), addrs)


def test_pipeline_remaps_failed_channel_traffic():
    cfg = MemoryControllerConfig(
        channels=ChannelConfig(num_channels=4),
        dram_sched=DRAMSchedConfig(policy="frfcfs", reorder_window=16))
    rng = np.random.default_rng(1)
    rows = rng.integers(0, 5000, 3000)
    rw = rng.integers(0, 2, 3000).astype(np.int32)
    healthy = MemoryController(cfg).simulate(None, rows, rw, 4096)
    res = MemoryController(cfg).simulate(
        None, rows, rw, 4096,
        faults=FaultConfig(failed_channels=(2,)))
    assert res.requests_per_channel[2] == 0
    assert sum(res.requests_per_channel) == healthy.n_requests
    # served slower on 3 survivors, but everything served
    assert res.makespan_fpga_cycles > healthy.makespan_fpga_cycles
    assert res.fault is not None and res.fault.n_dropped == 0


# ---------------------------------------------------------------------------
# Pipeline / controller threading
# ---------------------------------------------------------------------------

def test_pipeline_storm_stats_and_victim_slowdown():
    """An open-loop pipeline run under the ECC storm reports the
    aggregated FaultStats block, scatters dropped flags by seq, and
    the storm slows the tenants down in aggregate (replay re-admission
    may reorder the window, so a rare individual request can finish
    earlier — the distribution, not each request, must degrade)."""
    addrs_rows = np.random.default_rng(11)
    rows = addrs_rows.integers(0, 2000, 2500)
    rw = (addrs_rows.random(2500) < 0.3).astype(np.int32)
    pe = addrs_rows.integers(0, 2, 2500)
    arr = np.cumsum(-np.log(np.clip(addrs_rows.random(2500),
                                    1e-12, 1.0)) / 0.06)
    cfg = MemoryControllerConfig(
        num_pes=2,
        dram_sched=DRAMSchedConfig(policy="frfcfs", reorder_window=16))
    clean = MemoryController(cfg).simulate(
        pe, rows, rw, 4096, arrival_cycle=arr)
    storm = MemoryController(cfg).simulate(
        pe, rows, rw, 4096, arrival_cycle=arr,
        faults=dataclasses.replace(STORM, outage_windows=()))
    assert storm.fault.n_injected > 0
    assert storm.dropped is not None
    assert int(storm.dropped.sum()) == storm.fault.n_dropped
    ok = ~storm.dropped
    slower = (storm.serving.sojourn_fpga_cycles[ok]
              >= clean.serving.sojourn_fpga_cycles[ok] - 1e-9)
    assert slower.mean() > 0.95
    assert storm.serving.mean_sojourn > clean.serving.mean_sojourn
    assert storm.serving.p99_sojourn > clean.serving.p99_sojourn


def test_simulate_rejects_empty_trace_and_bad_inputs():
    mc = MemoryController(MemoryControllerConfig())
    with pytest.raises(ValueError, match="empty trace"):
        mc.simulate(None, np.empty(0, np.int64), None, 512)
    with pytest.raises(ValueError, match="finite and >= 0"):
        mc.simulate(None, np.arange(4), None, 512,
                    arrival_cycle=np.array([0.0, 1.0, -2.0, 3.0]))
    with pytest.raises(ValueError, match="one entry per request"):
        mc.simulate(None, np.arange(4), np.zeros(3, np.int32), 512)
    with pytest.raises(ValueError, match="one entry per request"):
        mc.simulate(None, np.arange(4), None, 512,
                    arrival_cycle=np.zeros(5))
