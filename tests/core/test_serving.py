"""Open-loop serving (arrival-aware oracles + pipeline) — the property
harness that locks the serving model down.

Serving adds *time of arrival* to the order-dependent service model of
``test_dram_sched.py``: requests enter per-port FIFOs at their stamp, an
arbiter grants arrived heads into the reorder window at issue pace, and
idle gaps advance the clock (absorbing refreshes). Every property is
stated against the request-at-a-time spec
(:func:`repro.core.timing.simulate_arrivals_seq`) or against the
closed-loop simulators the serving model must degenerate to:

* vectorized path == oracle, bit for bit (every count, the issue order,
  the grant order, and every per-request completion stamp), over arrival
  process x ports x arbiter policy x DRAM policy x window x cap x
  refresh x rw;
* ``arrival_cycle == 0`` == the closed-loop world exactly: single-port
  == ``simulate_dram_sched_seq``, multi-port == ``arbitrate_ports_seq``
  composed with it, and the full pipeline (stage stats, makespan, port
  stats, per-channel issue permutation) == the pre-serving pipeline;
* sojourn invariants: sojourn >= own service time, non-negative
  queueing delay, p50 <= p95 <= p99, makespan >= max(arrival+sojourn);
* the starvation cap still bounds grant-order slip under load;
* per-port FIFO order survives arbitration (weak-consistency rule);
* idle accounting is exact: with refresh off, busy + idle == makespan.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import pipeline as pl
from repro.core.channels import (ArbiterStats, arbitrate_ports_seq,
                                 simulate_serving_channels)
from repro.core.config import ChannelConfig, DRAMSchedConfig
from repro.core.timing import (DDR4_2400, HBM_V5E, simulate_arrivals,
                               simulate_arrivals_seq,
                               simulate_dram_sched_seq)
from repro.data.synthetic import (bursty_arrivals, diurnal_arrivals,
                                  hog_victim_workload, poisson_arrivals)


def _trace(reqs, timings):
    addrs = np.asarray([r[0] for r in reqs], np.int64) \
        * (timings.row_bytes // 2)
    rw = np.asarray([r[1] for r in reqs], np.int32)
    gaps = np.asarray([r[2] for r in reqs], np.float64)
    pe = np.asarray([r[3] for r in reqs], np.int64)
    return addrs, rw, np.cumsum(gaps), pe


def _assert_serving_equal(a, b):
    assert a.total_fpga_cycles == b.total_fpga_cycles
    assert a.row_hits == b.row_hits
    assert a.row_conflicts == b.row_conflicts
    assert a.first_accesses == b.first_accesses
    assert a.n_refreshes == b.n_refreshes
    assert a.refresh_dram_cycles == b.refresh_dram_cycles
    assert a.turnaround_dram_cycles == b.turnaround_dram_cycles
    assert a.idle_dram_cycles == b.idle_dram_cycles
    np.testing.assert_array_equal(a.service_order, b.service_order)
    np.testing.assert_array_equal(a.grant_order, b.grant_order)
    np.testing.assert_array_equal(a.granted_port, b.granted_port)
    np.testing.assert_array_equal(a.completion_fpga_cycles,
                                  b.completion_fpga_cycles)
    np.testing.assert_array_equal(a.service_dram_cycles,
                                  b.service_dram_cycles)


def _slips(order: np.ndarray) -> np.ndarray:
    """slip[i] = number of younger entries issued before entry i
    (indices are positions in the *grant* order)."""
    order = np.asarray(order, np.int64)
    n = order.shape[0]
    pos = np.empty(n, np.int64)
    pos[order] = np.arange(n)
    younger = np.arange(n)[None, :] > np.arange(n)[:, None]
    earlier = pos[None, :] < pos[:, None]
    return (younger & earlier).sum(axis=1)


# ---------------------------------------------------------------------------
# Vectorized path == request-at-a-time oracle (the headline identity)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 40),      # row
                          st.integers(0, 1),       # rw
                          st.sampled_from([0, 0, 1, 3, 9, 40]),  # gap
                          st.integers(0, 3)),      # port
                min_size=0, max_size=180),
       st.sampled_from(["fifo", "frfcfs", "frfcfs_cap"]),
       st.sampled_from([1, 2, 4, 16, 64]),
       st.sampled_from([1, 2, 8]),
       st.sampled_from([(0, 0), (0, 37), (30, 100), (30, 500)]),
       st.sampled_from([(1, "round_robin", None),
                        (2, "round_robin", None),
                        (4, "priority", None),
                        (3, "weighted", (3, 1, 2)),
                        (4, "weighted", (5, 1, 1, 2))]),
       st.booleans(),
       st.booleans())
def test_property_serving_fast_matches_oracle(reqs, policy, window, cap,
                                              refresh, arb, use_rw, hbm):
    t_rfc, t_refi = refresh
    nports, apol, weights = arb
    timings = HBM_V5E if hbm else DDR4_2400
    addrs, rw, arr, pe = _trace(reqs, timings)
    pe = pe % nports
    sched = DRAMSchedConfig(policy=policy, reorder_window=window,
                            starvation_cap=cap, t_rfc=t_rfc,
                            t_refi=t_refi)
    kw = dict(rw=rw if use_rw else None, arrival_fpga=arr,
              pe_id=pe if nports > 1 else None, num_ports=nports,
              arb_policy=apol, weights=weights)
    a = simulate_arrivals_seq(addrs, timings, sched, **kw)
    b = simulate_arrivals(addrs, timings, sched, **kw)
    _assert_serving_equal(a, b)
    assert np.array_equal(np.sort(a.service_order), np.arange(len(reqs)))
    assert np.array_equal(np.sort(a.grant_order), np.arange(len(reqs)))


# ---------------------------------------------------------------------------
# Closed-loop degeneracy: arrival_cycle == 0 is the pre-serving world
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 50), st.integers(0, 1),
                          st.sampled_from([0]), st.sampled_from([0])),
                min_size=0, max_size=200),
       st.sampled_from(["fifo", "frfcfs", "frfcfs_cap"]),
       st.sampled_from([1, 3, 16, 64]),
       st.sampled_from([2, 8]),
       st.sampled_from([(0, 0), (30, 120)]),
       st.booleans(),
       st.booleans())
def test_zero_arrivals_degenerate_to_dram_sched(reqs, policy, window, cap,
                                                refresh, use_rw, none_arr):
    """Single port, all-zero stamps: the serving oracle *is*
    ``simulate_dram_sched_seq`` — same makespan, counts and issue
    order — whether arrivals are omitted or explicit zeros."""
    t_rfc, t_refi = refresh
    addrs, rw, _, _ = _trace(reqs, DDR4_2400)
    sched = DRAMSchedConfig(policy=policy, reorder_window=window,
                            starvation_cap=cap, t_rfc=t_rfc,
                            t_refi=t_refi)
    arr = None if none_arr else np.zeros(len(reqs))
    a = simulate_arrivals_seq(addrs, DDR4_2400, sched,
                              rw=rw if use_rw else None, arrival_fpga=arr)
    b = simulate_dram_sched_seq(addrs, DDR4_2400, sched,
                                rw=rw if use_rw else None)
    assert a.total_fpga_cycles == b.total_fpga_cycles
    assert (a.row_hits, a.row_conflicts, a.first_accesses,
            a.n_refreshes, a.turnaround_dram_cycles) == \
           (b.row_hits, b.row_conflicts, b.first_accesses,
            b.n_refreshes, b.turnaround_dram_cycles)
    np.testing.assert_array_equal(a.service_order, b.service_order)
    assert a.idle_dram_cycles == 0.0


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 50), st.integers(0, 1),
                          st.sampled_from([0]), st.integers(0, 3)),
                min_size=1, max_size=200),
       st.sampled_from([("round_robin", None), ("priority", None),
                        ("weighted", (4, 1, 2, 1))]),
       st.sampled_from([1, 4, 32]),
       st.booleans())
def test_zero_arrivals_degenerate_to_arbiter_composition(reqs, arb, window,
                                                         use_rw):
    """Multi port, all-zero stamps: the coupled admission loop grants
    exactly the saturated arbiter's permutation, and service equals the
    closed-loop scheduler run on the arbitrated stream."""
    apol, weights = arb
    nports = 4
    addrs, rw, _, pe = _trace(reqs, DDR4_2400)
    sched = DRAMSchedConfig(policy="frfcfs", reorder_window=window)
    a = simulate_arrivals_seq(addrs, DDR4_2400, sched,
                              rw=rw if use_rw else None,
                              pe_id=pe, num_ports=nports,
                              arb_policy=apol, weights=weights)
    perm, stats = arbitrate_ports_seq(pe, num_ports=nports, policy=apol,
                                      weights=weights)
    b = simulate_dram_sched_seq(addrs[perm], DDR4_2400, sched,
                                rw=None if not use_rw else rw[perm])
    assert a.total_fpga_cycles == b.total_fpga_cycles
    np.testing.assert_array_equal(a.grant_order, perm)
    np.testing.assert_array_equal(a.service_order, perm[b.service_order])
    np.testing.assert_array_equal(
        ArbiterStats.from_grant_order(a.granted_port, nports).grants,
        stats.grants)


@pytest.mark.parametrize("nc", [1, 4])
@pytest.mark.parametrize("policy,window", [("fifo", 1), ("frfcfs", 16),
                                           ("frfcfs_cap", 32)])
@pytest.mark.parametrize("use_rw", [False, True])
def test_pipeline_degeneracy_bit_identical(nc, policy, window, use_rw):
    """The tentpole acceptance property: an ``arrival_cycle == 0``
    stream forced through the serving datapath reproduces the pre-PR
    pipeline bit for bit — makespan, every stage's cycles and request
    counts, port stats, and the per-channel issue permutation."""
    rng = np.random.default_rng(nc * 100 + window)
    n = 600
    addrs = rng.integers(0, 1 << 20, n).astype(np.int64) * 64
    rw = (rng.random(n) < 0.3).astype(np.int32) if use_rw else None
    pe = rng.integers(0, 4, n)
    sched = DRAMSchedConfig(policy=policy, reorder_window=window,
                            starvation_cap=8, t_refi=9363, t_rfc=420)

    def run(arrival, open_loop):
        stream = pl.RequestStream.from_addrs(addrs, rw, pe_id=pe,
                                             arrival_cycle=arrival)
        ctx = pl.PipelineContext(
            channels=ChannelConfig(num_channels=nc), scheduler=None,
            cache=None, timings=DDR4_2400, dram_sched=sched,
            open_loop=open_loop)
        stages = pl.default_stages(ctx, ports=4,
                                   arbiter_policy="weighted",
                                   weights=[4, 1, 2, 1], cache=False)
        return pl.run_pipeline(stream, ctx, stages)

    a = run(np.zeros(n), open_loop=True)    # serving datapath, forced
    b = run(None, open_loop=None)           # legacy closed-loop pipeline
    assert b.serving is None and a.serving is not None
    assert a.makespan_fpga_cycles == b.makespan_fpga_cycles
    assert a.dram_makespan_fpga_cycles == b.dram_makespan_fpga_cycles
    for sa, sb in zip(a.stages, b.stages):
        assert (sa.name, sa.cycles, sa.in_requests, sa.out_requests) == \
               (sb.name, sb.cycles, sb.in_requests, sb.out_requests)
    np.testing.assert_array_equal(a.port_stats.grants, b.port_stats.grants)
    np.testing.assert_array_equal(a.port_stats.stall_slots,
                                  b.port_stats.stall_slots)
    assert a.requests_per_channel == b.requests_per_channel
    # per-channel issue permutation: serving issues grant_order[order_b]
    # where order_b is the legacy post-arbitration issue order
    for pa, pb in zip(a.per_channel, b.per_channel):
        assert pa.total_fpga_cycles == pb.total_fpga_cycles
        assert (pa.row_hits, pa.row_conflicts, pa.first_accesses) == \
               (pb.row_hits, pb.row_conflicts, pb.first_accesses)
        np.testing.assert_array_equal(pa.service_order,
                                      pa.grant_order[pb.service_order])
    # degenerate sojourns: completion == sojourn (arrival 0), max ==
    # makespan, and the serving view is self-consistent
    s = a.serving
    assert a.makespan_fpga_cycles == float(s.completion_fpga_cycles.max())


# ---------------------------------------------------------------------------
# Sojourn invariants
# ---------------------------------------------------------------------------

def _serving_result(seed, gen, rate, nports=4, policy="weighted"):
    rng = np.random.default_rng(seed)
    n = 2500
    addrs = rng.integers(0, 1 << 20, n).astype(np.int64) * 64
    rw = (rng.random(n) < 0.25).astype(np.int32)
    pe = rng.integers(0, nports, n)
    arr = gen(rng, n, rate)
    stream = pl.RequestStream.from_addrs(addrs, rw, pe_id=pe,
                                         arrival_cycle=arr)
    ctx = pl.PipelineContext(
        channels=ChannelConfig(num_channels=2), scheduler=None,
        cache=None, timings=DDR4_2400, ctrl_overhead_cycles=10.0,
        dram_sched=DRAMSchedConfig(policy="frfcfs_cap", reorder_window=16,
                                   starvation_cap=8, t_refi=9363,
                                   t_rfc=420))
    stages = pl.default_stages(ctx, ports=nports, arbiter_policy=policy,
                               weights=[4, 1, 1, 1], cache=False)
    return pl.run_pipeline(stream, ctx, stages)


@pytest.mark.parametrize("gen,rate", [
    (poisson_arrivals, 0.3), (poisson_arrivals, 0.02),
    (bursty_arrivals, 0.1), (diurnal_arrivals, 0.1)])
def test_sojourn_invariants(gen, rate):
    res = _serving_result(7, gen, rate)
    s = res.serving
    soj = s.sojourn_fpga_cycles
    assert (soj >= s.service_fpga_cycles - 1e-9).all()
    assert (s.queueing_fpga_cycles >= -1e-9).all()
    assert s.p50_sojourn <= s.p95_sojourn <= s.p99_sojourn \
        <= s.worst_sojourn
    assert res.makespan_fpga_cycles >= \
        float((s.arrival_fpga_cycles + soj).max()) - 1e-9
    assert s.sustained_req_per_cycle > 0
    assert set(s.per_port) == {0, 1, 2, 3}
    assert sum(d["n"] for d in s.per_port.values()) == res.n_requests


def test_starvation_cap_bounds_grant_order_slip():
    """Under saturating load, frfcfs_cap still bounds how many younger
    *granted* requests may pass any request (the closed-loop slip bound
    restated in grant space)."""
    rng = np.random.default_rng(11)
    n = 1200
    cap = 4
    addrs = rng.integers(0, 1 << 18, n).astype(np.int64) * 64
    arr = poisson_arrivals(rng, n, 2.0)          # far beyond capacity
    pe = rng.integers(0, 2, n)
    res = simulate_arrivals(
        addrs, DDR4_2400,
        DRAMSchedConfig(policy="frfcfs_cap", reorder_window=32,
                        starvation_cap=cap),
        arrival_fpga=arr, pe_id=pe, num_ports=2)
    inv = np.empty(n, np.int64)
    inv[res.grant_order] = np.arange(n)
    order_in_grant_space = inv[res.service_order]
    assert _slips(order_in_grant_space).max() <= cap


def test_per_port_fifo_order_preserved():
    rng = np.random.default_rng(3)
    n = 2000
    addrs = rng.integers(0, 1 << 20, n).astype(np.int64) * 64
    arr = bursty_arrivals(rng, n, 0.2)
    pe = rng.integers(0, 4, n)
    for policy, w in [("round_robin", None), ("priority", None),
                      ("weighted", (4, 2, 1, 1))]:
        res = simulate_arrivals(
            addrs, DDR4_2400,
            DRAMSchedConfig(policy="frfcfs", reorder_window=16),
            arrival_fpga=arr, pe_id=pe, num_ports=4,
            arb_policy=policy, weights=w)
        for p in range(4):
            mine = res.grant_order[pe[res.grant_order] == p]
            assert (np.diff(mine) > 0).all()


def test_idle_gap_is_exact():
    """An isolated late request completes at arrival + its own service
    time, and with refresh off the clock decomposes exactly into busy
    + idle."""
    t = DDR4_2400
    addrs = np.array([0, t.row_bytes * t.num_banks * 4]) * 1
    arr = np.array([0.0, 5000.0])
    res = simulate_arrivals(addrs, t, DRAMSchedConfig(),
                            arrival_fpga=arr)
    np.testing.assert_allclose(
        res.completion_fpga_cycles[1],
        5000.0 + res.service_dram_cycles[1] * t.clock_ratio)
    rng = np.random.default_rng(0)
    n = 800
    a2 = rng.integers(0, 1 << 16, n).astype(np.int64) * 64
    arr2 = poisson_arrivals(rng, n, 0.01)        # mostly idle
    r2 = simulate_arrivals(a2, t, DRAMSchedConfig(policy="frfcfs",
                                                  reorder_window=8),
                           arrival_fpga=arr2)
    busy = int(r2.service_dram_cycles.sum())
    np.testing.assert_allclose(
        r2.total_fpga_cycles / t.clock_ratio,
        busy + r2.idle_dram_cycles)
    assert r2.idle_dram_cycles > 0


def test_arrival_validation():
    with pytest.raises(ValueError, match="arrival"):
        simulate_arrivals(np.array([0, 64]), DDR4_2400, DRAMSchedConfig(),
                          arrival_fpga=np.array([0.0, -1.0]))
    with pytest.raises(ValueError, match="arrival"):
        simulate_arrivals(np.array([0, 64]), DDR4_2400, DRAMSchedConfig(),
                          arrival_fpga=np.array([0.0]))
    with pytest.raises(ValueError):
        pl.RequestStream.from_addrs(np.array([0, 64]),
                                    arrival_cycle=np.array([0.0, np.inf]))


# ---------------------------------------------------------------------------
# Channels-layer composition + generators
# ---------------------------------------------------------------------------

def test_serving_channels_fast_matches_seq_oracle():
    rng = np.random.default_rng(9)
    n = 1500
    addrs = rng.integers(0, 1 << 22, n).astype(np.int64) * 64
    rw = (rng.random(n) < 0.3).astype(np.int32)
    arr = poisson_arrivals(rng, n, 0.15)
    pe = rng.integers(0, 4, n)
    kw = dict(pe_id=pe, num_ports=4, policy="weighted",
              weights=[4, 2, 1, 1],
              channel_cfg=ChannelConfig(num_channels=4, policy="xor"),
              dram_sched=DRAMSchedConfig(policy="frfcfs_cap",
                                         reorder_window=16,
                                         starvation_cap=8,
                                         t_refi=9363, t_rfc=420))
    a = simulate_serving_channels(addrs, arr, rw, use_seq_oracle=True,
                                  **kw)
    b = simulate_serving_channels(addrs, arr, rw, use_seq_oracle=False,
                                  **kw)
    assert a.makespan_fpga_cycles == b.makespan_fpga_cycles
    assert (a.row_hits, a.row_conflicts, a.first_accesses) == \
           (b.row_hits, b.row_conflicts, b.first_accesses)
    np.testing.assert_array_equal(a.completion_fpga_cycles,
                                  b.completion_fpga_cycles)
    np.testing.assert_array_equal(a.port_stats.grants, b.port_stats.grants)


def test_arrival_generators_are_calibrated_and_deterministic():
    n = 60000
    for gen in (poisson_arrivals, bursty_arrivals, diurnal_arrivals):
        a = gen(np.random.default_rng(0), n, 0.05)
        b = gen(np.random.default_rng(0), n, 0.05)
        np.testing.assert_array_equal(a, b)      # stream-stable draws
        assert (np.diff(a) >= 0).all() and a[0] >= 0
        rate = n / a[-1]
        assert 0.045 < rate < 0.055, (gen.__name__, rate)
    rows, rw, pe, arr = hog_victim_workload(
        np.random.default_rng(1), n_victim=500, n_hog=2000,
        victim_rate=0.01, hog_rate=0.2)
    assert (np.diff(arr) >= 0).all()
    assert set(np.unique(pe)) == {0, 1}
    assert (rw[pe == 0] == 0).all()              # victim is read-only


def test_controller_simulate_serving_entry():
    """``MemoryController.simulate(..., arrival_cycle=...)`` runs the
    drop-free serving subset and reports sojourns; the same call
    without stamps keeps the legacy closed-loop result shape."""
    from repro.core.config import MemoryControllerConfig
    from repro.core.controller import MemoryController

    rng = np.random.default_rng(2)
    rows, rw, pe, arr = hog_victim_workload(
        rng, n_victim=300, n_hog=1200, victim_rate=0.02, hog_rate=0.3)
    mc = MemoryController(MemoryControllerConfig(num_pes=2))
    res = mc.simulate(pe, rows, rw, 4096, arbiter_policy="weighted",
                      weights=[4, 1], arrival_cycle=arr)
    assert res.serving is not None
    assert res.stage("cache_filter") is None     # drop-free subset
    assert res.stage("batch_scheduler") is None
    assert res.serving.p99_sojourn >= res.serving.p50_sojourn > 0
    closed = mc.simulate(pe, rows, rw, 4096)
    assert closed.serving is None
