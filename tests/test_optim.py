"""Optimizer substrate: AdamW behaviour, schedule, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import (OptimizerConfig, adamw_update, init_opt_state,
                               lr_schedule)
from repro.optim.compress import compress_int8, decompress_int8


def test_adamw_optimizes_quadratic(key):
    params = {"w": jax.random.normal(key, (8,))}
    target = jnp.arange(8.0)
    cfg = OptimizerConfig(peak_lr=0.1, warmup_steps=1, total_steps=200,
                          weight_decay=0.0)
    opt = init_opt_state(params)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    loss0 = float(loss_fn(params))
    for _ in range(100):
        g = jax.grad(loss_fn)(params)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(loss_fn(params)) < 0.1 * loss0
    assert int(opt["step"]) == 100


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    cfg = OptimizerConfig(peak_lr=1e-2, warmup_steps=0, clip_norm=1.0)
    opt = init_opt_state(params)
    huge = {"w": jnp.full((4,), 1e9)}
    p2, _, metrics = adamw_update(huge, opt, params, cfg)
    assert float(metrics["grad_norm"]) > 1e9 - 1
    assert np.isfinite(np.asarray(p2["w"])).all()
    assert float(jnp.max(jnp.abs(p2["w"]))) < 1.0


def test_lr_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(lr_schedule(jnp.asarray(s), cfg)) for s in range(101)]
    assert lrs[0] == 0.0
    np.testing.assert_allclose(lrs[10], 1e-3, rtol=1e-5)
    assert all(a >= b - 1e-12 for a, b in zip(lrs[10:], lrs[11:]))
    np.testing.assert_allclose(lrs[100], 1e-4, rtol=1e-3)


def test_weight_decay_only_on_matrices(key):
    w2 = jax.random.normal(key, (4, 4)) * 10
    b1 = jax.random.normal(key, (4,)) * 10
    params = {"w": w2, "b": b1}
    cfg = OptimizerConfig(peak_lr=1e-2, warmup_steps=0, weight_decay=1.0)
    opt = init_opt_state(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(zero_g, opt, params, cfg)
    assert float(jnp.max(jnp.abs(p2["b"] - b1))) < 1e-6       # no decay
    assert float(jnp.max(jnp.abs(p2["w"] - w2))) > 1e-4       # decayed


def test_int8_compression_error_bounded(key):
    g = jax.random.normal(key, (1024,)) * 3.0
    q, scale = compress_int8(g)
    back = decompress_int8(q, scale)
    assert q.dtype == jnp.int8
    max_err = float(jnp.max(jnp.abs(back - g)))
    assert max_err <= float(scale) / 2 + 1e-6    # half-ulp rounding bound


def test_compressed_psum_error_feedback_unbiased():
    """Over repeated steps with error feedback, the accumulated applied
    gradient tracks the true gradient (bias vanishes)."""
    from repro.optim.compress import compressed_psum, init_residuals

    g = {"w": jnp.linspace(-2.0, 2.0, 64)}
    res = init_residuals(g)
    applied = jnp.zeros((64,))

    def one(axis_g, axis_r):
        # single-device psum via shard_map over a trivial mesh
        from repro.compat import P, shard_map
        mesh = jax.make_mesh((1,), ("pod",))
        f = shard_map(
            lambda gg, rr: compressed_psum(gg, rr, "pod", mode="int8"),
            mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
        return f(axis_g, axis_r)

    for _ in range(50):
        out, res = one(g, res)
        applied = applied + out["w"]
    want = g["w"] * 50
    # relative error of the running sum shrinks well below one quant step
    np.testing.assert_allclose(np.asarray(applied), np.asarray(want),
                               atol=0.05)
