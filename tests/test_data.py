"""Data pipeline: determinism, stateless resume, host sharding."""

import numpy as np
import pytest

from repro.configs import SHAPES, get_arch
from repro.configs.base import ShapeConfig
from repro.data.synthetic import SyntheticDataset, make_batch

SMALL = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")


def test_batches_deterministic_in_step():
    cfg = get_arch("yi-34b", smoke=True)
    a = make_batch(cfg, SMALL, step=17, seed=3)
    b = make_batch(cfg, SMALL, step=17, seed=3)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = make_batch(cfg, SMALL, step=18, seed=3)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_next_tokens():
    cfg = get_arch("yi-34b", smoke=True)
    b = make_batch(cfg, SMALL, step=0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_tokens_in_vocab_and_zipf_skewed():
    cfg = get_arch("yi-34b", smoke=True)
    big = ShapeConfig("t", seq_len=512, global_batch=8, kind="train")
    b = make_batch(cfg, big, step=0)
    toks = b["tokens"]
    assert toks.min() >= 0 and toks.max() < cfg.vocab_size
    # zipf: low ids dominate (vocabulary locality for the cache engine);
    # 64 ids out of 256 carry the majority of the mass
    assert (toks < 8).mean() > 0.2
    assert (toks < 64).mean() > 0.45


def test_host_sharding_partitions_batch():
    cfg = get_arch("yi-34b", smoke=True)
    full = SyntheticDataset(cfg, SMALL, seed=1).batch_at(5)
    parts = [SyntheticDataset(cfg, SMALL, seed=1, host_index=i,
                              host_count=4).batch_at(5)
             for i in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([p["tokens"] for p in parts]), full["tokens"])


@pytest.mark.parametrize("arch", ["hubert_xlarge", "internvl2_76b"])
def test_modality_stub_batches(arch):
    cfg = get_arch(arch, smoke=True)
    b = make_batch(cfg, SMALL, step=0)
    if cfg.modality == "audio":
        assert b["frames"].shape == (8, 32, cfg.frontend_dim)
    else:
        assert b["vision_embeds"].shape == (8, cfg.num_vision_tokens,
                                            cfg.frontend_dim)
        assert b["tokens"].shape == (8, 32 - cfg.num_vision_tokens)
