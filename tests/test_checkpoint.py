"""Checkpoint store: roundtrip, atomicity, retention, exotic dtypes."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              load_checkpoint, save_checkpoint)


def _tree(key):
    ks = jax.random.split(key, 3)
    return {
        "params": {"w": jax.random.normal(ks[0], (8, 4), jnp.bfloat16),
                   "b": jax.random.normal(ks[1], (4,), jnp.float32)},
        "opt": {"m": jnp.zeros((8, 4)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip_including_bf16(tmp_path, key):
    tree = _tree(key)
    save_checkpoint(str(tmp_path), 7, tree)
    back = load_checkpoint(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_ignores_partial(tmp_path, key):
    tree = _tree(key)
    save_checkpoint(str(tmp_path), 5, tree)
    save_checkpoint(str(tmp_path), 10, tree)
    # simulate a crash mid-save: tmp dir without manifest
    os.makedirs(tmp_path / "step_99.tmp")
    (tmp_path / "step_99.tmp" / "junk.npy").write_bytes(b"x")
    # and a finalized-looking dir without manifest
    os.makedirs(tmp_path / "step_50")
    assert latest_step(str(tmp_path)) == 10


def test_missing_leaf_raises(tmp_path, key):
    tree = _tree(key)
    save_checkpoint(str(tmp_path), 1, tree)
    bigger = {**tree, "extra": jnp.zeros((2,))}
    with pytest.raises(ValueError, match="missing leaves"):
        load_checkpoint(str(tmp_path), 1, bigger)


def test_manager_retention_and_async(tmp_path, key):
    tree = _tree(key)
    mgr = CheckpointManager(str(tmp_path), save_every=2, keep=2)
    for step in range(1, 9):
        mgr.maybe_save(step, tree)
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    assert steps == [6, 8]


def test_restore_latest_roundtrip(tmp_path, key):
    tree = _tree(key)
    mgr = CheckpointManager(str(tmp_path), save_every=1)
    mgr.maybe_save(3, tree)
    mgr.wait()
    step, back = mgr.restore_latest(tree)
    assert step == 3
    np.testing.assert_array_equal(
        np.asarray(back["params"]["w"], np.float32),
        np.asarray(tree["params"]["w"], np.float32))


def test_restore_latest_none_when_empty(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path))
    step, back = mgr.restore_latest(_tree(key))
    assert step is None and back is None
