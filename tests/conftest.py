"""Shared test fixtures. NOTE: no XLA_FLAGS here by design — unit tests and
benches must see the real single CPU device; multi-device distribution
tests spawn subprocesses with their own flags."""

try:
    import hypothesis  # noqa: F401  — real engine when available (CI)
except ImportError:    # hermetic environments: deterministic fallback
    from _hypothesis_fallback import install as _install_hypothesis
    _install_hypothesis()

import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.key(0)
