"""launch/report.py — dry-run/roofline table rendering, locked down by a
small fixture round-trip.

The report module renders EXPERIMENTS.md tables from the dry-run JSONL
records; these tests pin the record → table contract (latest-per-cell
dedup, failed-cell rows, byte formatting, mesh filtering, summary
extrema) so a rendering change can't silently corrupt the published
tables.
"""

import json

import pytest

from repro.launch import report


def _rec(cell, *, compile_s=12.0, state=3 << 30, temp=200 << 20,
         flops=1.5e15, compute_s=0.02, memory_s=0.04, collective_s=0.01,
         bottleneck="memory", useful=0.9, roofline=0.5, **extra):
    r = {
        "cell": cell, "compile_s": compile_s,
        "state_bytes_per_device": state,
        "memory_analysis": {"temp_size_in_bytes": temp},
        "hlo_flops": flops,
        "collectives_detail": {"all-gather": 1 << 20,
                               "all-reduce": 2 << 20},
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "bottleneck": bottleneck,
        "useful_flops_ratio": useful, "roofline_fraction": roofline,
    }
    r.update(extra)
    return r


@pytest.fixture
def jsonl(tmp_path):
    recs = [
        _rec("gpt-125m/base/1pod", roofline=0.7),
        _rec("gpt-125m/base/1pod", roofline=0.6),     # later wins dedup
        _rec("yi-34b/base/1pod", roofline=0.3, collective_s=0.05),
        _rec("yi-34b/base/2pod", roofline=0.4),
        {"cell": "broken/base/1pod", "error": "OOM during compile xyz"},
    ]
    p = tmp_path / "dryrun.jsonl"
    with open(p, "w") as fh:
        for r in recs:
            fh.write(json.dumps(r) + "\n")
    return str(p)


def test_load_dedups_latest_per_cell(jsonl):
    recs = report.load(jsonl)
    cells = sorted(r["cell"] for r in recs)
    assert cells == ["broken/base/1pod", "gpt-125m/base/1pod",
                     "yi-34b/base/1pod", "yi-34b/base/2pod"]
    gpt = next(r for r in recs if r["cell"] == "gpt-125m/base/1pod")
    assert gpt["roofline_fraction"] == 0.6      # the later record won


@pytest.mark.parametrize("b,expect", [
    (512, "0K"), (100 * 1024, "100K"),
    (5 << 20, "5.0M"), (3 << 30, "3.00G"),
])
def test_fmt_bytes(b, expect):
    assert report.fmt_bytes(b) == expect


def test_dryrun_table_rows_and_failures(jsonl):
    recs = report.load(jsonl)
    table = report.dryrun_table(recs)
    lines = table.splitlines()
    assert lines[0].startswith("| cell |")
    assert lines[1].startswith("|---")
    # one row per cell, sorted, failures rendered inline
    assert len(lines) == 2 + 4
    assert "FAILED: OOM during compile xyz" in table
    # the arch/shape splits off the mesh column
    assert "| gpt-125m/base | 1pod |" in table
    assert "3.00G" in table and "200.0M" in table


def test_roofline_table_filters_mesh(jsonl):
    recs = report.load(jsonl)
    t1 = report.roofline_table(recs, "1pod")
    t2 = report.roofline_table(recs, "2pod")
    assert "gpt-125m/base" in t1 and "yi-34b/base" in t1
    assert "gpt-125m/base" not in t2 and "yi-34b/base" in t2
    assert "**memory**" in t1
    # failed cells never make it into the roofline
    assert "broken" not in t1


def test_summary_extrema(jsonl):
    recs = report.load(jsonl)
    s = report.summary(recs)
    assert "cells compiled OK: 3; failed: 1" in s
    # worst single-pod roofline fraction is yi-34b (0.3)
    assert "worst roofline fraction: yi-34b/base/1pod" in s
    assert "most collective-exposed: yi-34b/base/1pod" in s


def test_round_trip_through_main_render(jsonl, capsys):
    """The full ``main``-shaped render path on the fixture file."""
    recs = report.load(jsonl)
    out = "\n".join([report.summary(recs), report.dryrun_table(recs),
                     report.roofline_table(recs, "1pod"),
                     report.roofline_table(recs, "2pod")])
    # every surviving cell appears somewhere, and the output is
    # markdown-table shaped (every table line pipes out)
    for cell in ("gpt-125m/base", "yi-34b/base", "broken/base"):
        assert cell in out
    for line in report.dryrun_table(recs).splitlines():
        assert line.startswith("|") and line.endswith("|")
