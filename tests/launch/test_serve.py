"""Serving driver (launch/serve.py) — smoke + tenant-isolation regression.

The serve driver now replays its batched-decode KV access stream through
``MemoryController.simulate`` in open-loop mode (ARCHITECTURE §9), so a
serve run reports modeled memory sojourns per tenant. These tests pin:

* the smoke path populates the modeled stats (finite, ordered
  percentiles, one per-tenant record per issuing tenant);
* the isolation property the serving stack exists for — with a
  bandwidth-hog tenant sharing the controller, weighted arbitration
  protects the SLO tenant's p99 where round_robin does not.

Model forward passes are real (smoke-sized) jitted JAX; keep sizes tiny.
"""

import numpy as np
import pytest

from repro.core.config import MemoryControllerConfig
from repro.launch.serve import Request, Server


def _requests(rng, *, n_victim=4, n_hog=8, victim_prompt=8, hog_prompt=48,
              hog_new=24):
    """Victim tenant 0: short sparse prompts. Hog tenant 1: long prompts
    + deep decode arriving in a burst — the KV stream it induces floods
    the shared controller."""
    reqs = []
    for i in range(n_victim):
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, 250, victim_prompt)
            .astype(np.int32),
            max_new_tokens=4, arrival_cycle=i * 40, tenant=0))
    for j in range(n_hog):
        reqs.append(Request(
            rid=100 + j, prompt=rng.integers(0, 250, hog_prompt)
            .astype(np.int32),
            max_new_tokens=hog_new, arrival_cycle=j, tenant=1))
    return reqs


def _serve(arb_policy, weights, reqs):
    server = Server("h2o-danube-1.8b", smoke=True,
                    mem=MemoryControllerConfig(num_pes=2),
                    arb_policy=arb_policy, arb_weights=weights,
                    decode_interval_cycles=16)
    return server.serve([Request(**r.__dict__) for r in reqs])


def test_serve_smoke_reports_modeled_memory():
    rng = np.random.default_rng(0)
    stats = _serve("round_robin", None, _requests(rng))
    assert stats.requests == 12 and stats.batches >= 1
    assert stats.decode_steps > 0
    assert 0 < stats.modeled_p50_cycles <= stats.modeled_p95_cycles \
        <= stats.modeled_p99_cycles
    assert stats.modeled_makespan_cycles >= stats.modeled_p99_cycles
    assert set(stats.modeled_per_tenant) == {0, 1}
    for t, rec in stats.modeled_per_tenant.items():
        assert rec["n"] > 0
        assert rec["p50_sojourn"] <= rec["p99_sojourn"]
    # hog emits far more KV traffic than the victim
    assert stats.modeled_per_tenant[1]["n"] > \
        stats.modeled_per_tenant[0]["n"] * 3


def test_weighted_arbitration_protects_victim_tenant():
    """Tenant-isolation regression: same request set, same model, only
    the arbiter differs. Weighted (favoring the SLO tenant) must give
    the victim a strictly better modeled p99 than round_robin, which
    splits grants evenly with the hog's flood."""
    rng = np.random.default_rng(1)
    reqs = _requests(rng)
    rr = _serve("round_robin", None, reqs)
    wt = _serve("weighted", [8, 1], reqs)
    v_rr = rr.modeled_per_tenant[0]["p99_sojourn"]
    v_wt = wt.modeled_per_tenant[0]["p99_sojourn"]
    assert v_wt < v_rr, (v_wt, v_rr)
    # the victim's traffic is identical either way — only service changed
    assert rr.modeled_per_tenant[0]["n"] == wt.modeled_per_tenant[0]["n"]


def test_serve_outputs_and_admission_unchanged():
    """The memory model rides alongside the functional path — outputs
    and batch formation must be identical with it active."""
    rng = np.random.default_rng(2)
    reqs = _requests(rng, n_victim=2, n_hog=2, hog_new=4)
    stats = _serve("round_robin", None, reqs)
    assert stats.requests == 4
    # serve() filled outputs on its own copies; rerun on shared objects
    server = Server("h2o-danube-1.8b", smoke=True,
                    mem=MemoryControllerConfig(num_pes=2))
    server.serve(reqs)
    for r in reqs:
        assert r.output is not None and len(r.output) == r.max_new_tokens
