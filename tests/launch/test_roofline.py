"""Roofline machinery: HLO collective parser + term math."""

import numpy as np
import pytest

from repro.launch.roofline import (RooflineReport, collective_bytes_from_hlo,
                                   model_flops_for)
from repro.configs import SHAPES, get_arch

HLO_SAMPLE = """
ENTRY %main {
  %ag = f32[1024,64]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256]T(1,0), dimensions={0}
  %ar = bf16[512,128]{1,0} all-reduce(%y), replica_groups=[16,16]<=[256], to_apply=%add
  %rs = f32[64,64]{1,0} reduce-scatter(%z), replica_groups=[16,16]<=[256], dimensions={0}
  %a2a = bf16[32,32]{1,0} all-to-all(%w), replica_groups=[16,16]<=[256]
  %cp = f32[16,16]{1,0} collective-permute(%v), source_target_pairs={{0,1}}
  %ags = (f32[8,8]{1,0}, f32[128,8]{1,0}) all-gather-start(%u), replica_groups=[16,16]<=[256], dimensions={0}
  %agd = f32[128,8]{1,0} all-gather-done(%ags)
}
"""


def test_collective_parser_kinds_and_sizes():
    out = collective_bytes_from_hlo(HLO_SAMPLE)
    assert out["all-gather"] == 1024 * 64 * 4 + 128 * 8 * 4  # plain + start
    assert out["all-reduce"] == 512 * 128 * 2
    assert out["reduce-scatter"] == 64 * 64 * 4 * 16         # out x group
    assert out["all-to-all"] == 32 * 32 * 2
    assert out["collective-permute"] == 16 * 16 * 4


def test_parser_skips_done_ops():
    out = collective_bytes_from_hlo(
        "%d = f32[128,8]{1,0} all-gather-done(%s)\n")
    assert out["all-gather"] == 0


def test_report_terms_and_bottleneck():
    r = RooflineReport(name="t", chips=256, hlo_flops=1e18,
                       hbm_bytes=1e15, collective_bytes=1e9,
                       collectives_detail={}, model_flops=5e17)
    np.testing.assert_allclose(r.compute_s, 1e18 / (256 * 197e12))
    np.testing.assert_allclose(r.memory_s, 1e15 / (256 * 819e9))
    np.testing.assert_allclose(r.collective_s, 1e9 / (4 * 50e9))
    assert r.bottleneck == "compute"
    np.testing.assert_allclose(r.useful_flops_ratio, 0.5)
    assert 0 < r.roofline_fraction <= 1.0


def test_model_flops_semantics():
    cfg = get_arch("yi-34b")
    n = cfg.active_param_count()
    train = model_flops_for(cfg, SHAPES["train_4k"], n)
    decode = model_flops_for(cfg, SHAPES["decode_32k"], n)
    np.testing.assert_allclose(train, 6 * n * SHAPES["train_4k"].tokens)
    np.testing.assert_allclose(decode, 2 * n * 128)   # one token per seq


def test_moe_active_params_below_total():
    q = get_arch("qwen2-moe-a2.7b")
    assert q.active_param_count() < 0.35 * q.param_count()
