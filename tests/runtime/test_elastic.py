"""Dedicated elastic-rescale unit tests: the TP-preservation policy,
largest-fitting data axis, pod-granularity shrink, and the
global-batch-via-grad-accum invariant.

``test_fault_tolerance.py`` keeps the end-to-end smoke cases; the
planner's arithmetic edges live here.
"""

import pytest

from repro.runtime import elastic_mesh_shape, plan_rescale


def _dp(plan):
    sizes = dict(zip(plan.axis_names, plan.new_shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)


def test_mesh_shape_exact_and_truncated_fits():
    assert elastic_mesh_shape(256, 16) == (16, 16)
    # 250 chips / model 16 -> data axis is the largest multiple (15)
    assert elastic_mesh_shape(250, 16) == (15, 16)
    # single-pod meshes are 2-tuples, multi-pod 3-tuples
    assert elastic_mesh_shape(512, 16, pods=2) == (2, 16, 16)
    assert elastic_mesh_shape(510, 16, pods=2) == (2, 15, 16)


def test_mesh_shape_never_shrinks_tp():
    with pytest.raises(ValueError, match="cannot shrink TP"):
        elastic_mesh_shape(8, 16)
    # enough chips in total but not per pod: still refused
    with pytest.raises(ValueError):
        elastic_mesh_shape(24, 16, pods=2)


@pytest.mark.parametrize("lost", [0, 16, 48, 112])
def test_plan_preserves_model_axis_size(lost):
    plan = plan_rescale((16, 16), ("data", "model"),
                        available_devices=256 - lost, global_batch=512)
    assert dict(zip(plan.axis_names, plan.new_shape))["model"] == 16


def test_plan_no_loss_is_identity():
    plan = plan_rescale((16, 16), ("data", "model"),
                        available_devices=256, global_batch=512)
    assert plan.new_shape == (16, 16)
    assert plan.grad_accum == 1
    assert plan.dropped_devices == 0


@pytest.mark.parametrize("available,want_dp,want_accum", [
    (128, 8, 2),    # half the fleet -> half the DP, 2x accumulation
    (240, 15, 2),   # odd shrink: ceil(16/15) = 2 keeps the batch whole
    (64, 4, 4),
])
def test_plan_preserves_global_batch(available, want_dp, want_accum):
    plan = plan_rescale((16, 16), ("data", "model"),
                        available_devices=available, global_batch=256)
    assert _dp(plan) == want_dp
    assert plan.grad_accum == want_accum
    # the invariant the accumulation factor exists for: DP x accum
    # covers the old DP, so the global batch per optimizer step holds
    assert _dp(plan) * plan.grad_accum >= 16


def test_plan_drops_partial_pod_wholesale():
    """A pod is only kept with its full chip complement — a pod that
    lost chips is written off entirely (its survivors are unusable
    ICI-wise), and the data axis absorbs the rest."""
    plan = plan_rescale((2, 8, 16), ("pod", "data", "model"),
                        available_devices=200, global_batch=256)
    # full pod = 8*16 = 128 chips; 200 available -> only 1 intact pod
    assert plan.axis_names == ("data", "model")
    assert plan.new_shape == (12, 16)       # 200 // 16 = 12 data shards
    assert plan.grad_accum == 2             # old DP 16 -> new DP 12
    assert plan.dropped_devices == 200 - 12 * 16


def test_plan_keeps_both_pods_when_complete():
    plan = plan_rescale((2, 8, 16), ("pod", "data", "model"),
                        available_devices=300, global_batch=256)
    assert plan.axis_names == ("pod", "data", "model")
    assert plan.new_shape == (2, 9, 16)     # 150 per pod -> 9 data shards
    assert plan.dropped_devices == 300 - 2 * 9 * 16
    assert "grad_accum" in plan.describe()
