"""Dedicated StepWatchdog unit tests: baseline warmup, the robust
median baseline, patience/reset semantics, and alert plumbing.

``test_fault_tolerance.py`` keeps the two end-to-end smoke cases; the
state-machine edges live here. Step durations are simulated by
rewinding ``_t0`` (the pattern the smoke tests established) so the
suite never sleeps.
"""

import pytest

from repro.runtime import StepWatchdog


def _step(wd, dt, step):
    wd.start()
    wd._t0 -= dt
    return wd.stop(step)


def test_no_alerts_during_warmup():
    """Until max(5, window//5) samples exist there is no baseline, so
    even grossly slow steps cannot alert (nothing to compare against)."""
    wd = StepWatchdog(window=50, threshold=2.0, patience=1)
    for s in range(10):                      # warmup floor is 10 here
        assert _step(wd, 10.0 if s % 2 else 0.01, s) is None
    assert wd.alerts == []


def test_baseline_is_median_not_mean():
    """A few slow steps already inside the window must not drag the
    baseline up — the median ignores them where a mean would not."""
    wd = StepWatchdog(window=20, threshold=2.0, patience=1)
    for s in range(8):
        _step(wd, 0.01, s)
    for s in range(8, 11):                   # 3 outliers of 19 samples
        _step(wd, 1.0, s)
    assert wd.median_step_s == pytest.approx(0.01, rel=0.2)
    # a 3x-median step still trips against the 10ms baseline
    alert = _step(wd, 0.03, 11)
    assert alert is not None
    assert alert.baseline_s == pytest.approx(0.01, rel=0.2)
    assert alert.ratio == pytest.approx(3.0, rel=0.2)


def test_patience_requires_consecutive_breaches():
    """breach, recover, breach — the good step resets the counter, so
    patience=2 never fires."""
    wd = StepWatchdog(window=20, threshold=2.0, patience=2)
    for s in range(10):
        _step(wd, 0.01, s)
    assert _step(wd, 0.1, 10) is None
    assert _step(wd, 0.01, 11) is None       # resets _breaches
    assert _step(wd, 0.1, 12) is None        # count restarts at 1
    assert wd.alerts == []


def test_breach_counter_resets_after_alert():
    """Firing consumes the patience budget: the next alert needs a full
    new run of consecutive breaches."""
    wd = StepWatchdog(window=20, threshold=2.0, patience=2)
    for s in range(10):
        _step(wd, 0.01, s)
    assert _step(wd, 0.08, 10) is None
    assert _step(wd, 0.08, 11) is not None   # fires at patience=2
    assert _step(wd, 0.08, 12) is None       # counter was reset
    # note: breached steps enter the window, so keep the baseline fresh
    assert len(wd.alerts) == 1


def test_on_alert_callback_and_alert_fields():
    seen = []
    wd = StepWatchdog(window=20, threshold=2.0, patience=1,
                      on_alert=seen.append)
    for s in range(10):
        _step(wd, 0.01, s)
    alert = _step(wd, 0.05, 10)
    assert seen == [alert] == wd.alerts
    assert alert.step == 10
    assert alert.step_time_s == pytest.approx(0.05, rel=0.2)
    assert alert.ratio == pytest.approx(
        alert.step_time_s / alert.baseline_s)


def test_baseline_adapts_to_new_regime():
    """A persistent slowdown becomes the *new* baseline once it fills
    the window — the watchdog flags stragglers, not regime changes."""
    wd = StepWatchdog(window=10, threshold=2.0, patience=1)
    for s in range(10):
        _step(wd, 0.01, s)
    for s in range(10, 30):                  # 20 slow steps: window turns over
        _step(wd, 0.05, s)
    assert wd.median_step_s == pytest.approx(0.05, rel=0.2)
    assert _step(wd, 0.06, 30) is None       # normal under the new regime
    assert len(wd.times) == 10               # deque bounded by window


def test_stop_without_start_asserts():
    wd = StepWatchdog()
    with pytest.raises(AssertionError):
        wd.stop(0)


def test_median_of_empty_history_is_zero():
    assert StepWatchdog().median_step_s == 0.0
