"""Runtime: straggler watchdog + elastic rescale planning."""

import time

import pytest

from repro.runtime import StepWatchdog, elastic_mesh_shape, plan_rescale


def test_watchdog_flags_persistent_straggler():
    wd = StepWatchdog(window=20, threshold=2.0, patience=2)
    # baseline: fast steps
    for s in range(10):
        wd.start()
        wd._t0 -= 0.01        # simulate 10ms without sleeping
        wd.stop(s)
    # two consecutive slow steps -> alert on the second
    wd.start(); wd._t0 -= 0.1; assert wd.stop(10) is None
    wd.start(); wd._t0 -= 0.1; alert = wd.stop(11)
    assert alert is not None and alert.ratio > 2.0


def test_watchdog_ignores_single_blip():
    wd = StepWatchdog(window=20, threshold=2.0, patience=2)
    for s in range(10):
        wd.start(); wd._t0 -= 0.01; wd.stop(s)
    wd.start(); wd._t0 -= 0.2; assert wd.stop(10) is None   # one blip
    wd.start(); wd._t0 -= 0.01; assert wd.stop(11) is None  # recovered
    assert wd.alerts == []


def test_elastic_preserves_model_axis():
    assert elastic_mesh_shape(512, 16, pods=2) == (2, 16, 16)
    assert elastic_mesh_shape(256, 16) == (16, 16)
    # lose 3 hosts (12 chips): data shrinks, model survives
    assert elastic_mesh_shape(244, 16) == (15, 16)


def test_elastic_refuses_to_shrink_tp():
    with pytest.raises(ValueError):
        elastic_mesh_shape(8, 16)


def test_plan_rescale_accumulates_to_preserve_batch():
    plan = plan_rescale((16, 16), ("data", "model"),
                        available_devices=128, global_batch=256)
    assert plan.new_shape == (8, 16)
    assert plan.grad_accum == 2          # half the DP -> 2x accumulation
    assert plan.dropped_devices == 0


def test_plan_rescale_drops_dead_pod():
    plan = plan_rescale((2, 16, 16), ("pod", "data", "model"),
                        available_devices=256, global_batch=256)
    assert plan.new_shape == (16, 16)
    assert plan.grad_accum == 2
