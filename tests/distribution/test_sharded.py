"""Distribution tests on 8 fake CPU devices (subprocess: device count must
be set before jax initializes, and the main test process keeps 1 device).

Validates: (a) the sharded train step runs and matches the single-device
step numerically; (b) the dry-run cost-extrapolation methodology is exact
on a model small enough to fully unroll; (c) elastic restore onto a
different mesh preserves values.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def run_sub(body: str) -> dict:
    """Run `body` in a subprocess with 8 host devices; expects it to print
    a single JSON line prefixed RESULT:."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT:")][-1]
    return json.loads(line[len("RESULT:"):])


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    res = run_sub("""
        from repro.configs import get_arch
        from repro.models.lm import build_lm
        from repro.optim.adamw import OptimizerConfig, adamw_update, \\
            init_opt_state, opt_state_specs
        from repro.data.synthetic import SyntheticDataset
        from repro.configs.base import ShapeConfig
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = get_arch("yi-34b", smoke=True)
        shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
        data = SyntheticDataset(cfg, shape, seed=0)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

        def step(lm, params, opt, batch):
            (loss, m), g = jax.value_and_grad(lm.loss, has_aux=True)(
                params, batch)
            params, opt, _ = adamw_update(g, opt, params,
                                          OptimizerConfig(warmup_steps=1))
            return loss, params

        # single device
        lm1 = build_lm(cfg)
        p1 = lm1.init(jax.random.key(0))
        o1 = init_opt_state(p1)
        loss1, p1n = jax.jit(lambda p, o, b: step(lm1, p, o, b))(p1, o1,
                                                                 batch)

        # 4x2 mesh
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        lm2 = build_lm(cfg, mesh, global_batch=8)
        p2 = lm2.init(jax.random.key(0))
        o2 = init_opt_state(p2)
        named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                       is_leaf=lambda x: isinstance(x, P))
        ps = lm2.param_specs()
        fn = jax.jit(lambda p, o, b: step(lm2, p, o, b),
                     in_shardings=(named(ps), named(opt_state_specs(ps)),
                                   None))
        loss2, p2n = fn(p2, o2, batch)
        dmax = max(float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1n), jax.tree.leaves(p2n)))
        print("RESULT:" + json.dumps(
            {"loss1": float(loss1), "loss2": float(loss2), "dmax": dmax}))
    """)
    assert abs(res["loss1"] - res["loss2"]) < 5e-3
    assert res["dmax"] < 5e-2


@pytest.mark.slow
def test_cost_extrapolation_exact_on_unrollable_model():
    """total = cost(G1) + (G-1)(cost(G2)-cost(G1)) must equal the cost of
    the fully-unrolled G-group model (the dry-run's core assumption)."""
    res = run_sub("""
        import dataclasses
        from repro.configs import get_arch
        from repro.launch import roofline
        from repro.launch.dryrun import build_cell
        import repro.launch.dryrun as dr

        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             devices=jax.devices()[:4])

        def cost_for(nl, scan):
            fn, args, _, _, _ = build_cell(
                "h2o-danube-1.8b", "train_4k", mesh,
                overrides={"num_layers": nl, "scan_layers": scan,
                           "d_model": 64, "num_heads": 4,
                           "num_kv_heads": 2, "d_ff": 128,
                           "vocab_size": 256, "head_dim": 16,
                           "attn_window": 8})
            comp = fn.lower(*args).compile()
            return roofline.analyze("x", comp, chips=4, model_flops=0)

        g1 = cost_for(1, False)
        g2 = cost_for(2, False)
        g6 = cost_for(6, False)            # ground truth, unrolled
        extrap = g1.hlo_flops + 5 * (g2.hlo_flops - g1.hlo_flops)
        extrap_coll = g1.collective_bytes + 5 * (
            g2.collective_bytes - g1.collective_bytes)
        print("RESULT:" + json.dumps({
            "true": g6.hlo_flops, "extrap": extrap,
            "true_coll": g6.collective_bytes,
            "extrap_coll": extrap_coll}))
    """)
    assert res["true"] > 0
    # Error bars measured on this deliberately tiny config (d=64): ~6-9%
    # FLOPs, ~15% collectives — fusion boundaries and XLA's
    # depth-dependent collective combining are a visible share at toy
    # scale. At production scale the uniform layer term is >99% of cost.
    # These bounds are documented in EXPERIMENTS.md's methodology note.
    assert abs(res["extrap"] - res["true"]) / res["true"] < 0.12
    if res["true_coll"] > 0:
        assert abs(res["extrap_coll"] - res["true_coll"]) \
            / res["true_coll"] < 0.20


@pytest.mark.slow
def test_ep_dispatch_matches_tp_and_trains():
    """Expert-parallel (shard_map all_to_all) MoE must value-match the TP
    dispatch and run a full sharded train step."""
    res = run_sub("""
        import dataclasses
        from repro.configs import get_arch
        from repro.models import blocks
        from repro.models.lm import build_lm
        from repro.models.moe_ep import moe_ffn_ep
        from repro.models.sharding import make_rules
        from repro.optim.adamw import OptimizerConfig, adamw_update, \\
            init_opt_state, opt_state_specs
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_arch("jamba-v0.1-52b", smoke=True)
        cfgf = dataclasses.replace(
            cfg, param_dtype="float32",
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        lm0 = build_lm(cfgf)
        params0 = lm0.init(jax.random.key(0))
        pos = next(k for k, v in params0["layers"].items() if "moe" in v)
        p = jax.tree.map(lambda t: t[0], params0["layers"][pos]["moe"])
        x = jax.random.normal(jax.random.key(2), (4, 16, cfgf.d_model),
                              jnp.float32)
        want, _ = blocks.moe_ffn(p, x, cfgf, make_rules(None), None)
        with mesh:
            got, _ = jax.jit(lambda p, x: moe_ffn_ep(p, x, cfgf, mesh))(p, x)
        err = float(jnp.max(jnp.abs(got - want)))

        lm = build_lm(cfg, mesh, global_batch=8, moe_strategy="ep")
        params = lm.init(jax.random.key(0))
        opt = init_opt_state(params)
        toks = jax.random.randint(jax.random.key(1), (8, 32), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                       is_leaf=lambda s: isinstance(s, P))
        def step(p, o, b):
            (loss, m), g = jax.value_and_grad(lm.loss, has_aux=True)(p, b)
            p, o, _ = adamw_update(g, o, p, OptimizerConfig(warmup_steps=1))
            return loss
        ps = lm.param_specs()
        loss = jax.jit(step, in_shardings=(named(ps),
                                           named(opt_state_specs(ps)),
                                           None))(params, opt, batch)
        print("RESULT:" + json.dumps({"err": err, "loss": float(loss)}))
    """)
    assert res["err"] < 1e-4
    assert np.isfinite(res["loss"])


@pytest.mark.slow
def test_elastic_restore_onto_smaller_mesh(tmp_path):
    res = run_sub(f"""
        from repro.configs import get_arch
        from repro.models.lm import build_lm
        from repro.checkpoint import save_checkpoint, load_checkpoint
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = get_arch("yi-34b", smoke=True)
        mesh8 = jax.make_mesh((4, 2), ("data", "model"))
        lm = build_lm(cfg, mesh8)
        params = lm.init(jax.random.key(0))
        save_checkpoint("{tmp_path}", 3, params)

        # "failure": restore onto a 2x2 mesh (half the fleet)
        mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                              devices=jax.devices()[:4])
        lm4 = build_lm(cfg, mesh4)
        back = load_checkpoint("{tmp_path}", 3, params, mesh=mesh4,
                               specs=lm4.param_specs())
        ok = all(
            bool(jnp.all(a.astype(jnp.float32) == b.astype(jnp.float32)))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)))
        shardings = jax.tree.leaves(back)[0].sharding.mesh.shape
        print("RESULT:" + json.dumps(
            {{"equal": ok, "mesh": dict(shardings)}}))
    """)
    assert res["equal"]
    assert res["mesh"] == {"data": 2, "model": 2}
