"""Fig. 8 — 16 KiB sequential access vs PE<->controller interface width.

Cache-line path: the PE issues 16 KiB / width requests; each 64 B cache
line misses once (compulsory) then hits for the remaining sub-line
requests, so narrow interfaces multiply on-chip beats AND expose the first-
element miss latency per line. DMA path: one bulk descriptor; the engine
streams the whole region as sequential bursts. Claim: ~20x advantage for
DMA at the narrowest interface (paper §V-C).
"""

import numpy as np

from benchmarks.common import emit
from repro.core.config import PAPER_EVAL_CONFIG
from repro.core.timing import DDR4_2400, simulate_dram_access

TOTAL = 16 * 1024


def run() -> None:
    cfg = PAPER_EVAL_CONFIG
    t = DDR4_2400
    line = cfg.cache.line_bytes

    # DMA path: one descriptor, sequential burst stream
    bursts = np.arange(0, TOTAL, t.burst_bytes, dtype=np.int64)
    dma_cycles = (simulate_dram_access(bursts, t).total_fpga_cycles
                  + cfg.ctrl_overhead_cycles + 2)

    for width in (1, 2, 4, 8, 16, 32, 64):
        n_req = TOTAL // width
        n_lines = TOTAL // line
        # per line: one miss (DRAM access, sequential rows) + the remaining
        # (line/width - 1) requests hit in the cache at 1 beat each
        miss_addrs = np.arange(n_lines, dtype=np.int64) * line
        miss_cycles = simulate_dram_access(miss_addrs, t).total_fpga_cycles
        hit_beats = n_req - n_lines
        cache_cycles = (miss_cycles + hit_beats
                        + cfg.ctrl_overhead_cycles + 4)
        emit(f"fig8/width{width}B", 0.0,
             f"cache_cycles={cache_cycles:.0f}|dma_cycles={dma_cycles:.0f}|"
             f"dma_speedup={cache_cycles / dma_cycles:.1f}x")


if __name__ == "__main__":
    run()
