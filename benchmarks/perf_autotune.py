"""Batched autotune benchmark — the full TUNE grid as one stacked sweep.

Scores the whole default autotune grid (batch × associativity × lines ×
dma, optionally × channels × DRAM-sched variants) two ways and proves
they agree: ``tune(engine="oracle")`` walks the grid one candidate at a
time through the staged pipeline; ``tune(engine="batched")`` hoists the
dma axis, vectorizes the constant-arrival batch plan, and classifies
the strict-FIFO service term with one fused key sort per variant.
Tables and argmin must be bit-identical — the benchmark asserts it on
every row before recording wall time and configs/second.

Workload choice matters for the headline: on *line-granular* gather
traces (64-byte rows over a 1M-entry table) the cache filter stays on
its vectorized path and the per-config scheduling cost dominates, so
the batched engine's win is visible end to end. On *row-granular*
traces (row-sized strides) the shared, memoized cache filter falls back
to its sequential LRU walk and dominates both engines equally — that
row is recorded too, honestly labeled, so the JSON shows where the
speedup comes from.

Writes ``BENCH_autotune.json``; ``--small`` (~50k requests) is the CI
perf-smoke configuration.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.core.autotune import tune

FULL_SIZE = 200_000


def _grid_size(res) -> int:
    return len(res.table)


def _tune_both(rows, row_bytes, label, results, *, assert_speedup=None,
               note=None, **grid):
    t0 = time.perf_counter()
    oracle = tune(rows, row_bytes, engine="oracle", **grid)
    t_oracle = time.perf_counter() - t0
    t0 = time.perf_counter()
    batched = tune(rows, row_bytes, engine="batched", **grid)
    t_batched = time.perf_counter() - t0

    identical = (oracle.table == batched.table
                 and oracle.config == batched.config
                 and oracle.modeled_cycles == batched.modeled_cycles
                 and oracle.candidates_evaluated
                 == batched.candidates_evaluated)
    assert identical, f"batched tune diverged from oracle on {label}"

    speedup = t_oracle / t_batched
    if assert_speedup is not None:
        assert speedup >= assert_speedup, (
            f"{label}: batched speedup {speedup:.1f}x below the "
            f"{assert_speedup}x floor")
    rec = {
        "n_requests": int(len(rows)),
        "grid_points": _grid_size(oracle),
        "candidates_evaluated": oracle.candidates_evaluated,
        "oracle_s": round(t_oracle, 3),
        "batched_s": round(t_batched, 3),
        "speedup": round(speedup, 1),
        "oracle_configs_per_sec": round(
            oracle.candidates_evaluated / t_oracle, 1),
        "batched_configs_per_sec": round(
            batched.candidates_evaluated / t_batched, 1),
        "identical_table_and_argmin": identical,
        "best_modeled_cycles": batched.modeled_cycles,
    }
    if note:
        rec["note"] = note
    results["workloads"][label] = rec
    emit(f"perf_autotune/{label}", t_batched * 1e6,
         f"speedup={speedup:.1f}x|grid={rec['grid_points']}|"
         f"batched_cfg_per_s={rec['batched_configs_per_sec']}|"
         f"identical={identical}")
    return rec


def run(n_requests: int = FULL_SIZE) -> dict:
    rng = np.random.default_rng(0)
    results: dict = {
        "benchmark": "batched_autotune_grid",
        "unit": "wall_seconds",
        "n_requests": n_requests,
        "note": ("tune(engine='batched') vs tune(engine='oracle') on "
                 "identical grids; tables and argmin asserted "
                 "bit-identical on every row"),
        "workloads": {},
    }

    # Headline: uniform 64B-line gathers over a 1M-entry table — low
    # hit rate keeps the post-filter miss stream large, so per-config
    # plan+service cost dominates and the batched engine's win is the
    # end-to-end number. Full default grid (384 points).
    full = n_requests >= FULL_SIZE
    _tune_both(rng.integers(0, 1 << 20, n_requests).astype(np.int64),
               64, "uniform_gather_1M_64B", results,
               assert_speedup=10.0 if full else None)

    # Skewed gathers — zipf(1.05) over the same table; mild reuse, the
    # filter still vectorizes, speedup stays >10x at full size.
    _tune_both(((rng.zipf(1.05, n_requests) - 1) % (1 << 20))
               .astype(np.int64),
               64, "zipf1.05_gather_1M_64B", results)

    # Extended sweep axes: channels × DRAM-sched variants on top of the
    # cache/batch grid — the "(cache × channels × sched × window)" axis
    # from the issue, at a quarter of the trace to keep the oracle side
    # affordable.
    _tune_both(rng.integers(0, 1 << 20, max(1, n_requests // 4))
               .astype(np.int64),
               64, "extended_grid_chan_sched", results,
               num_channels=(1, 2),
               mapping_policies=("row_interleave", "xor"),
               dram_sched_policies=("fifo", "frfcfs"),
               reorder_windows=(1, 8))

    # Row-granular GCN-like trace: row-sized strides alias the cache
    # sets, the shared memoized filter walks its sequential LRU path,
    # and both engines pay it equally — recorded so the headline's
    # provenance is explicit.
    _tune_both(((rng.zipf(1.2, n_requests) - 1) % 2048).astype(np.int64),
               4096, "gcn_row_granular_4KB", results,
               note=("shared sequential cache-filter walk dominates "
                     "both engines on row-granular traces; speedup "
                     "here measures only the scheduling/service term"))

    head = results["workloads"]["uniform_gather_1M_64B"]
    results["headline_speedup_batched_vs_oracle"] = head["speedup"]
    results["all_rows_identical"] = all(
        w["identical_table_and_argmin"]
        for w in results["workloads"].values())

    write_bench_json("autotune", results)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--small", action="store_true",
                    help="CI perf-smoke size (~50k requests)")
    ap.add_argument("--n", type=int, default=None,
                    help="override trace length")
    args = ap.parse_args()
    n = args.n or (50_000 if args.small else FULL_SIZE)
    print("name,us_per_call,derived")
    run(n)


if __name__ == "__main__":
    main()
