"""RAS / fault-injection benchmark — what an error storm costs the
victim tenant under each retry policy, and what graceful degradation
buys (ARCHITECTURE §10).

Stage 1 measures the fault-free capacity of the two-tenant serving
configuration (the perf_serving methodology). Stage 2 is the acceptance
sweep (ISSUE 7): escalating error rates x three retry policies on the
same hog-vs-victim arrival stream —

* ``bounded_backoff`` — SECDED + bounded replay (max 4 attempts) with
  exponential backoff: a failing request leaves the bus between
  attempts, so the storm's cost to the *victim tenant's p99* stays
  bounded, at the price of dropping requests whose budget exhausts;
* ``naive_retry``   — SECDED + immediate retry (no backoff, deep
  budget): every hard error hammers the bus back-to-back and the
  victim pays for it at high error rates;
* ``no_ecc``        — detection off (``ecc="none"``, no write CRC):
  nothing is replayed so nothing slows down, but every injected error
  is *silent data corruption* — recorded so the timing win is never
  mistaken for a free lunch.

Machine-readable acceptance: ``bounded_beats_naive_victim_p99`` (at the
top error rate) and ``no_ecc_fast_but_corrupts``. Stage 3 pins the
degradation contract: a channel-outage run serves *slower* but drops
*nothing* (``outage_served_slower_zero_drops``). Stage 4 records the
fault engine's fast-path speedup over the request-at-a-time oracle.

Writes ``BENCH_faults.json``; ``--small`` (~30k requests) is the CI
perf-smoke configuration.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from benchmarks.perf_pipeline import ROW_BYTES
from repro.core.config import (CacheConfig, DRAMSchedConfig, FaultConfig,
                               MemoryControllerConfig, SchedulerConfig)
from repro.core.controller import MemoryController
from repro.core.timing import (DDR4_2400, simulate_faults,
                               simulate_faults_seq)
from repro.data.synthetic import hog_victim_workload, poisson_arrivals

T_RFC, T_REFI = 420, 9363
ERROR_RATES = (0.0005, 0.005, 0.02)

BARE = MemoryControllerConfig(
    scheduler=SchedulerConfig(enabled=False),
    cache=CacheConfig(enabled=False))
SERVICE = DRAMSchedConfig(policy="frfcfs_cap", reorder_window=32,
                          starvation_cap=16, t_rfc=T_RFC, t_refi=T_REFI)

# The storm shape shared by every policy: transient errors everywhere
# plus hard-failed weak cells (every access errors). Hard failures are
# the case the retry policy actually decides: immediate retry burns the
# full replay budget back-to-back on the bus, bounded backoff spreads a
# smaller budget out and then gives up.
STORM_BASE = FaultConfig(seed=9, weak_row_fraction=0.02, weak_row_ber=1.0,
                         due_fraction=1.0)

POLICIES = {
    "bounded_backoff": dict(max_replays=4, backoff_clocks=64),
    "naive_retry": dict(max_replays=16, backoff_clocks=0),
    "no_ecc": dict(ecc="none", write_crc=False),
}


def _simulate(cfg, pe, rows, rw, arr, *, policy="weighted",
              weights=(4, 1), faults=None):
    mc = MemoryController(cfg)
    t0 = time.perf_counter()
    res = mc.simulate(pe, rows, rw, ROW_BYTES, arbiter_policy=policy,
                      weights=weights, arrival_cycle=arr, faults=faults)
    return res, (time.perf_counter() - t0) * 1e6


def run(n_requests: int = 120_000) -> dict:
    n_victim = max(200, n_requests // 5)
    n_hog = n_requests - n_victim
    cfg = dataclasses.replace(BARE, dram_sched=SERVICE, num_pes=2)

    # ---- stage 1: fault-free reference on the two-tenant stream ------
    probe_rows, probe_rw, probe_pe, _ = hog_victim_workload(
        np.random.default_rng(4), n_victim=n_victim, n_hog=n_hog,
        victim_rate=1.0, hog_rate=1.0)
    closed, dt = _simulate(cfg, probe_pe, probe_rows, probe_rw, None)
    capacity = n_requests / closed.makespan_fpga_cycles
    rows, rw, pe, arr = hog_victim_workload(
        np.random.default_rng(4), n_victim=n_victim, n_hog=n_hog,
        victim_rate=0.15 * capacity, hog_rate=0.75 * capacity)
    clean, dt = _simulate(cfg, pe, rows, rw, arr)
    clean_victim_p99 = clean.serving.per_port[0]["p99_sojourn"]
    emit("perf_faults/clean_reference", dt,
         f"capacity={capacity:.5f}req_per_cycle|"
         f"victim_p99={clean_victim_p99:.1f}")

    results: dict = {
        "benchmark": "fault_storm_retry_policies",
        "unit": "modeled_fpga_cycles",
        "n_requests": n_requests,
        "row_bytes": ROW_BYTES,
        "service": {"policy": SERVICE.policy,
                    "reorder_window": SERVICE.reorder_window,
                    "starvation_cap": SERVICE.starvation_cap,
                    "t_rfc": T_RFC, "t_refi": T_REFI},
        "capacity_req_per_cycle": capacity,
        "clean_victim_p99": round(clean_victim_p99, 1),
        "error_rates": list(ERROR_RATES),
        "sweep": {},
    }

    # ---- stage 2: error-rate x retry-policy sweep --------------------
    for ber in ERROR_RATES:
        row: dict = {}
        for label, knobs in POLICIES.items():
            fc = dataclasses.replace(STORM_BASE, transient_ber=ber,
                                     **knobs)
            res, dt = _simulate(cfg, pe, rows, rw, arr, faults=fc)
            st = res.fault
            per = res.serving.per_port
            row[label] = {
                "victim_p99": round(per[0]["p99_sojourn"], 1),
                "hog_p99": round(per[1]["p99_sojourn"], 1),
                "n_injected": st.n_injected,
                "n_corrected": st.n_corrected,
                "n_replays": st.n_replays,
                "n_dropped": st.n_dropped,
                "n_silent": st.n_silent,
                "replay_dram_cycles": st.replay_dram_cycles,
                "makespan": round(res.makespan_fpga_cycles, 1),
            }
            emit(f"perf_faults/ber{ber:g}_{label}", dt,
                 f"victim_p99={row[label]['victim_p99']}|"
                 f"replays={st.n_replays}|dropped={st.n_dropped}|"
                 f"silent={st.n_silent}")
        results["sweep"][f"{ber:g}"] = row

    top = results["sweep"][f"{ERROR_RATES[-1]:g}"]
    results["bounded_beats_naive_victim_p99"] = bool(
        top["bounded_backoff"]["victim_p99"]
        < top["naive_retry"]["victim_p99"])
    results["no_ecc_fast_but_corrupts"] = bool(
        top["no_ecc"]["victim_p99"]
        <= top["bounded_backoff"]["victim_p99"]
        and top["no_ecc"]["n_silent"] > 0
        and top["bounded_backoff"]["n_silent"] == 0)

    # ---- stage 3: channel outage degrades gracefully -----------------
    span = float(arr.max())
    outage = FaultConfig(seed=9, outage_windows=(
        (0, int(0.2 * span), int(0.45 * span)),))
    deg, dt = _simulate(cfg, pe, rows, rw, arr, faults=outage)
    results["outage"] = {
        "window_dram_clocks": [int(0.2 * span), int(0.45 * span)],
        "outage_dram_cycles": round(deg.fault.outage_dram_cycles, 1),
        "clean_p99": round(clean.serving.p99_sojourn, 1),
        "outage_p99": round(deg.serving.p99_sojourn, 1),
        "clean_makespan": round(clean.makespan_fpga_cycles, 1),
        "outage_makespan": round(deg.makespan_fpga_cycles, 1),
        "n_dropped": deg.fault.n_dropped,
    }
    results["outage_served_slower_zero_drops"] = bool(
        deg.serving.p99_sojourn > clean.serving.p99_sojourn
        and deg.makespan_fpga_cycles >= clean.makespan_fpga_cycles
        and deg.fault.n_dropped == 0)
    emit("perf_faults/channel_outage", dt,
         f"p99={results['outage']['outage_p99']}"
         f"(clean={results['outage']['clean_p99']})|dropped=0")

    # ---- stage 4: fault engine fast path vs oracle -------------------
    n_perf = min(15_000, n_requests)
    fc = dataclasses.replace(STORM_BASE, transient_ber=0.005,
                             max_replays=4, backoff_clocks=64)
    addrs = rows[:n_perf] * ROW_BYTES
    arr_p = poisson_arrivals(np.random.default_rng(5), n_perf,
                             capacity * 0.8)
    t0 = time.perf_counter()
    oracle = simulate_faults_seq(addrs, DDR4_2400, SERVICE,
                                 rw=rw[:n_perf], faults=fc,
                                 arrival_fpga=arr_p)
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    fast = simulate_faults(addrs, DDR4_2400, SERVICE, rw=rw[:n_perf],
                           faults=fc, arrival_fpga=arr_p)
    t_fast = time.perf_counter() - t0
    assert fast.total_fpga_cycles == oracle.total_fpga_cycles
    assert fast.fault.as_dict() == oracle.fault.as_dict()
    results["simulator"] = {
        "n": n_perf,
        "oracle_s": round(t_seq, 3),
        "fast_s": round(t_fast, 3),
        "speedup": round(t_seq / t_fast, 1),
    }
    emit("perf_faults/simulator_fast_vs_oracle", t_fast * 1e6,
         f"speedup={t_seq / t_fast:.1f}x|n={n_perf}")

    write_bench_json("faults", results)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--small", action="store_true",
                    help="CI perf-smoke size (~30k requests)")
    ap.add_argument("--n", type=int, default=None,
                    help="override trace length")
    args = ap.parse_args()
    n = args.n or (30_000 if args.small else 120_000)
    print("name,us_per_call,derived")
    run(n)


if __name__ == "__main__":
    main()
