"""Multi-channel / multi-port front end: modeled makespan sweeps.

Four probes over the new ``repro.core.channels`` subsystem, on the
GCN-style (Zipf-hot irregular) and CNN-style (sliding-window) traces the
trace-engine benchmark established:

  channels  — modeled makespan vs channel count (1→8), DDR4 vs HBM_V5E:
              the channel-parallel speedup the paper's single-interface
              design leaves on the table, and the acceptance check that
              GCN makespan improves monotonically from 1→4 channels.
  mapping   — policy sweep (row/block/xor) at 4 channels, including a
              power-of-two-stride trace where plain interleave camps on
              one channel and the XOR fold restores balance.
  contention— multi-PE curves: 1→8 ports sharing 4 channels under each
              arbiter policy, reporting makespan, per-port stalls and
              Jain fairness (the Memory-Controller-Wall contention
              story).
  order     — verifies per-port arrival order survives into every
              channel queue for every policy (recorded in the JSON so
              the acceptance criterion is machine-checkable).

Writes ``BENCH_channels.json``; ``--small`` (~50k requests) is the CI
perf-smoke configuration.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.core.channels import (per_port_order_preserved,
                                 schedule_and_simulate_channels,
                                 simulate_multiport_channels)
from repro.core.config import ChannelConfig, SchedulerConfig
from repro.core.timing import DDR4_2400, HBM_V5E

ROW_BYTES = 4096


def gcn_style_trace(rng, n, n_rows):
    """Zipf-hot vertex rows (α=1.1), mixed read/write — the skewed
    irregular stream of the Fig. 7 GCN workload."""
    verts = (rng.zipf(1.1, n) - 1) % n_rows
    addrs = verts.astype(np.int64) * ROW_BYTES
    rw = rng.integers(0, 2, n).astype(np.int32)
    return addrs, rw


def cnn_style_trace(rng, n, n_rows):
    """Sliding conv windows with periodic activation write-backs."""
    sweep = (np.arange(n) // 4) % (n_rows - 8)
    addrs = (sweep + rng.integers(0, 8, n)).astype(np.int64) * ROW_BYTES
    rw = (np.arange(n) % 8 == 7).astype(np.int32)
    return addrs, rw


def sweep_channels(traces, sched, results):
    out = {}
    for tname, (addrs, rw) in traces.items():
        out[tname] = {}
        for mem_name, timings in (("DDR4_2400", DDR4_2400),
                                  ("HBM_V5E", HBM_V5E)):
            curve = {}
            for c in (1, 2, 4, 8):
                t0 = time.perf_counter()
                r = schedule_and_simulate_channels(
                    addrs, rw, sched_config=sched, timings=timings,
                    channel_cfg=ChannelConfig(num_channels=c))
                dt = (time.perf_counter() - t0) * 1e6
                curve[str(c)] = {
                    "makespan_fpga_cycles": round(r.makespan_fpga_cycles),
                    "busy_fpga_cycles": round(r.busy_fpga_cycles),
                    "row_hit_rate": round(r.hit_rate, 4),
                    "speedup_vs_1ch": None,     # filled below
                }
                if c == 1:
                    base = r.makespan_fpga_cycles
                curve[str(c)]["speedup_vs_1ch"] = round(
                    base / max(r.makespan_fpga_cycles, 1e-9), 3)
                emit(f"perf_channels/{tname}/{mem_name}/ch{c}", dt,
                     f"makespan={curve[str(c)]['makespan_fpga_cycles']}|"
                     f"speedup_vs_1ch={curve[str(c)]['speedup_vs_1ch']}x")
            makespans = [curve[str(c)]["makespan_fpga_cycles"]
                         for c in (1, 2, 4)]
            curve["monotonic_1_to_4"] = bool(
                makespans[0] > makespans[1] > makespans[2])
            out[tname][mem_name] = curve
    results["channel_sweep"] = out


def sweep_mapping(traces, sched, n, results):
    """Mapping-policy sweep at 4 channels; the strided trace is the
    pathological case plain interleave camps on."""
    stride = ChannelConfig(num_channels=4,
                           policy="block_interleave").interleave_bytes * 4
    strided = (np.arange(n, dtype=np.int64) % (1 << 14)) * stride
    cases = dict(traces)
    cases["strided_pow2"] = (strided, np.zeros(n, np.int32))
    out = {}
    for tname, (addrs, rw) in cases.items():
        out[tname] = {}
        for policy in ("row_interleave", "block_interleave", "xor"):
            cfg = ChannelConfig(num_channels=4, policy=policy)
            r = schedule_and_simulate_channels(
                addrs, rw, sched_config=sched, timings=DDR4_2400,
                channel_cfg=cfg)
            load = np.asarray(r.requests_per_channel, np.float64)
            imbalance = float(load.max() / max(load.mean(), 1e-9))
            out[tname][policy] = {
                "makespan_fpga_cycles": round(r.makespan_fpga_cycles),
                "channel_load_imbalance": round(imbalance, 3),
            }
            emit(f"perf_channels/mapping/{tname}/{policy}", 0.0,
                 f"makespan={out[tname][policy]['makespan_fpga_cycles']}|"
                 f"imbalance={imbalance:.2f}x")
    results["mapping_sweep"] = out


def sweep_contention(traces, sched, rng, results):
    out = {}
    cfg4 = ChannelConfig(num_channels=4)
    for tname, (addrs, rw) in traces.items():
        n = addrs.shape[0]
        out[tname] = {}
        for ports in (1, 2, 4, 8):
            pe = rng.integers(0, ports, n)
            row = {}
            for policy in ("round_robin", "priority", "weighted"):
                weights = (2 ** (np.arange(ports) % 3)).tolist() \
                    if policy == "weighted" else None
                r = simulate_multiport_channels(
                    pe, addrs, rw, num_ports=ports, policy=policy,
                    weights=weights, timings=DDR4_2400, channel_cfg=cfg4,
                    sched_config=sched)
                row[policy] = {
                    "makespan_fpga_cycles": round(r.makespan_fpga_cycles),
                    "arbitration_cycles": r.arbitration_cycles,
                    "fairness": round(r.port_stats.fairness, 4),
                    "mean_stall_slots_per_grant": round(
                        float(r.port_stats.stall_slots.sum())
                        / max(1, int(r.port_stats.grants.sum())), 3),
                }
            out[tname][str(ports)] = row
            emit(f"perf_channels/contention/{tname}/ports{ports}", 0.0,
                 f"rr_makespan={row['round_robin']['makespan_fpga_cycles']}|"
                 f"rr_fairness={row['round_robin']['fairness']}")
    results["contention"] = out


def check_port_order(rng, n, results):
    """Machine-checkable acceptance record: per-port arrival order is
    preserved into every channel queue under every arbiter policy
    (shared predicate with tests/core/test_channels_equiv.py)."""
    pe = rng.integers(0, 8, n)
    addrs = (rng.integers(0, 1 << 14, n) * 512).astype(np.int64)
    ok = all(per_port_order_preserved(
        pe, addrs, num_ports=8,
        channel_cfg=ChannelConfig(num_channels=4),
        policy=policy, weights=w)
        for policy, w in (("round_robin", None), ("priority", None),
                          ("weighted", [1, 2, 1, 4, 1, 1, 2, 1])))
    results["per_port_order_preserved"] = ok
    emit("perf_channels/per_port_order", 0.0, f"preserved={ok}")


def run(n_requests: int = 200_000) -> dict:
    rng = np.random.default_rng(0)
    n_rows = 1 << 14
    sched = SchedulerConfig(batch_size=64)
    traces = {
        "gcn_style": gcn_style_trace(rng, n_requests, n_rows),
        "cnn_style": cnn_style_trace(rng, n_requests, n_rows),
    }
    results = {
        "benchmark": "channel_front_end",
        "unit": "modeled_fpga_cycles",
        "n_requests": n_requests,
        "note": ("makespan = slowest channel + arbitration fill; "
                 "channel-parallel fast path is bit-identical to the "
                 "sequential oracle (tests/core/test_channels_equiv.py)"),
    }
    sweep_channels(traces, sched, results)
    sweep_mapping(traces, sched, min(n_requests, 65536), results)
    sweep_contention(traces, sched, rng, results)
    check_port_order(rng, min(n_requests, 50_000), results)
    write_bench_json("channels", results)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--small", action="store_true",
                    help="CI perf-smoke size (~50k requests)")
    ap.add_argument("--n", type=int, default=None,
                    help="override trace length")
    args = ap.parse_args()
    n = args.n or (50_000 if args.small else 200_000)
    print("name,us_per_call,derived")
    run(n)


if __name__ == "__main__":
    main()
