"""Benchmark harness utilities. Output contract: one CSV line per probe,
``name,us_per_call,derived`` (derived = the paper-claim metric the probe
reproduces, e.g. an improvement percentage). Probes that feed the repo's
perf trajectory additionally write machine-readable ``BENCH_<name>.json``
summaries via :func:`write_bench_json`."""

from __future__ import annotations

import json
import time
from typing import Callable

import jax


def time_call(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time of fn(*args) in microseconds (blocks on jax)."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r) if hasattr(r, "block_until_ready") or \
            isinstance(r, (jax.Array, tuple, list, dict)) else None
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        if isinstance(r, (jax.Array,)):
            r.block_until_ready()
        else:
            jax.tree.map(lambda x: x.block_until_ready()
                         if isinstance(x, jax.Array) else x, r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def write_bench_json(name: str, payload: dict, directory: str = ".") -> str:
    """Persist a benchmark summary as ``BENCH_<name>.json`` so the perf
    trajectory accumulates machine-readable artifacts (CI uploads them
    per PR) instead of stdout-only CSV."""
    path = f"{directory}/BENCH_{name}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path
