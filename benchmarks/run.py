"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (harness contract). Every
probe reproduces one published artifact:

  table3  — cache resource utilization vs parameters   (Table III)
  fig5    — DMA engine resource utilization             (Fig. 5)
  fig6    — scheduler cost vs batch size + Eq. 1        (Fig. 6)
  fig7    — GCN 27% / CNN 58% access-time improvement   (Fig. 7)
  fig7w   — write-heavy streams (embed-grad, KV append) (Fig. 7 ext.)
  fig8    — interface-width sweep, 20x DMA advantage    (Fig. 8)
  fig9    — schedule-time breakdown, 32-64 optimum      (Fig. 9)
  autotune— TUNE-parameter search convergence           (§II, Table I)
  pipeline— combined cache+scheduler+channels config    (Fig. 7 composed)

The paper-claim probes (fig7 / fig7w / pipeline) also persist
machine-readable ``BENCH_fig7.json`` / ``BENCH_fig7_write.json`` /
``BENCH_pipeline.json`` summaries so the repo's perf trajectory
accumulates per PR (every probe runs at full size here so the tracked
artifacts stay stable; CI smoke uses ``--small``). The serving-stack
probes run from here too: ``perf_serving`` (open-loop latency/
throughput + tenant isolation, ``BENCH_serving.json``),
``perf_faults`` (RAS degradation sweep, ``BENCH_faults.json``) and
``perf_telemetry`` (tracing-off bit-identity + tracing-on overhead,
``BENCH_telemetry.json``). Only the minutes-long engine microbenches
stay separate: ``benchmarks/perf_trace_engine.py`` writes
``BENCH_trace_engine.json`` for the simulator's own throughput,
``benchmarks/perf_channels.py`` writes ``BENCH_channels.json`` for
the multi-channel / multi-port front end, and
``benchmarks/perf_dram_sched.py`` writes ``BENCH_dram_sched.json``
for the out-of-order DRAM command scheduler sweep.
"""

from benchmarks import (autotune_bench, fig5_dma_resources,
                        fig6_scheduler_cost, fig7_workloads,
                        fig7_write_workloads, fig8_interface_width,
                        fig9_schedule_time, perf_faults, perf_pipeline,
                        perf_serving, perf_telemetry,
                        table3_cache_resources)
from benchmarks.common import write_bench_json


def main() -> None:
    print("name,us_per_call,derived")
    table3_cache_resources.run()
    fig5_dma_resources.run()
    fig6_scheduler_cost.run()
    write_bench_json("fig7", fig7_workloads.run())
    write_bench_json("fig7_write", fig7_write_workloads.run())
    fig8_interface_width.run()
    fig9_schedule_time.run()
    autotune_bench.run()
    # Full size, so the tracked BENCH_*.json acceptance artifacts are
    # never overwritten with CI-size numbers (CI runs --small).
    perf_pipeline.run()            # writes BENCH_pipeline.json itself
    perf_serving.run()             # writes BENCH_serving.json itself
    perf_faults.run()              # writes BENCH_faults.json itself
    perf_telemetry.run()           # writes BENCH_telemetry.json itself


if __name__ == "__main__":
    main()
