"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (harness contract). Every
probe reproduces one published artifact:

  table3  — cache resource utilization vs parameters   (Table III)
  fig5    — DMA engine resource utilization             (Fig. 5)
  fig6    — scheduler cost vs batch size + Eq. 1        (Fig. 6)
  fig7    — GCN 27% / CNN 58% access-time improvement   (Fig. 7)
  fig7w   — write-heavy streams (embed-grad, KV append) (Fig. 7 ext.)
  fig8    — interface-width sweep, 20x DMA advantage    (Fig. 8)
  fig9    — schedule-time breakdown, 32-64 optimum      (Fig. 9)
  autotune— TUNE-parameter search convergence           (§II, Table I)
  pipeline— combined cache+scheduler+channels config    (Fig. 7 composed)

The paper-claim probes (fig7 / fig7w / pipeline) also persist
machine-readable ``BENCH_fig7.json`` / ``BENCH_fig7_write.json`` /
``BENCH_pipeline.json`` summaries so the repo's perf trajectory
accumulates per PR (every probe runs at full size here so the tracked
artifacts stay stable; CI smoke uses ``--small``). The serving-stack
probes run from here too: ``perf_serving`` (open-loop latency/
throughput + tenant isolation, ``BENCH_serving.json``),
``perf_autotune`` (batched vs one-at-a-time full-grid tune,
``BENCH_autotune.json``), ``perf_faults`` (RAS degradation sweep,
``BENCH_faults.json``), ``perf_telemetry`` (tracing-off
bit-identity + tracing-on overhead, ``BENCH_telemetry.json``) and
``perf_model_traces`` (captured per-architecture workload zoo replayed
through simulate() + the batched autotune grid,
``BENCH_model_traces.json``). A
per-benchmark wall-time table prints at the end of the run. Only the
minutes-long engine microbenches
stay separate: ``benchmarks/perf_trace_engine.py`` writes
``BENCH_trace_engine.json`` for the simulator's own throughput,
``benchmarks/perf_channels.py`` writes ``BENCH_channels.json`` for
the multi-channel / multi-port front end, and
``benchmarks/perf_dram_sched.py`` writes ``BENCH_dram_sched.json``
for the out-of-order DRAM command scheduler sweep.
"""

import time

from benchmarks import (autotune_bench, fig5_dma_resources,
                        fig6_scheduler_cost, fig7_workloads,
                        fig7_write_workloads, fig8_interface_width,
                        fig9_schedule_time, perf_autotune, perf_faults,
                        perf_model_traces, perf_pipeline, perf_serving,
                        perf_telemetry, table3_cache_resources)
from benchmarks.common import write_bench_json


def main() -> None:
    print("name,us_per_call,derived")
    timings: list[tuple[str, float]] = []

    def timed(name, fn):
        t0 = time.perf_counter()
        out = fn()
        timings.append((name, time.perf_counter() - t0))
        return out

    timed("table3", table3_cache_resources.run)
    timed("fig5", fig5_dma_resources.run)
    timed("fig6", fig6_scheduler_cost.run)
    write_bench_json("fig7", timed("fig7", fig7_workloads.run))
    write_bench_json("fig7_write",
                     timed("fig7w", fig7_write_workloads.run))
    timed("fig8", fig8_interface_width.run)
    timed("fig9", fig9_schedule_time.run)
    timed("autotune_convergence", autotune_bench.run)
    # Full size, so the tracked BENCH_*.json acceptance artifacts are
    # never overwritten with CI-size numbers (CI runs --small).
    timed("perf_pipeline", perf_pipeline.run)   # BENCH_pipeline.json
    timed("perf_serving", perf_serving.run)     # BENCH_serving.json
    timed("perf_autotune", perf_autotune.run)   # BENCH_autotune.json
    timed("perf_faults", perf_faults.run)       # BENCH_faults.json
    timed("perf_telemetry", perf_telemetry.run)  # BENCH_telemetry.json
    timed("perf_model_traces",                  # BENCH_model_traces.json
          perf_model_traces.run)

    # Wall-time summary — where a full `python -m benchmarks.run`
    # actually spends its minutes.
    total = sum(dt for _, dt in timings)
    width = max(len(n) for n, _ in timings)
    print(f"\n{'benchmark':<{width}}  wall_s  share")
    for name, dt in sorted(timings, key=lambda t: -t[1]):
        print(f"{name:<{width}}  {dt:6.1f}  {dt / total:5.1%}")
    print(f"{'total':<{width}}  {total:6.1f}")


if __name__ == "__main__":
    main()
