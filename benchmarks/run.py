"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (harness contract). Every
probe reproduces one published artifact:

  table3  — cache resource utilization vs parameters   (Table III)
  fig5    — DMA engine resource utilization             (Fig. 5)
  fig6    — scheduler cost vs batch size + Eq. 1        (Fig. 6)
  fig7    — GCN 27% / CNN 58% access-time improvement   (Fig. 7)
  fig7w   — write-heavy streams (embed-grad, KV append) (Fig. 7 ext.)
  fig8    — interface-width sweep, 20x DMA advantage    (Fig. 8)
  fig9    — schedule-time breakdown, 32-64 optimum      (Fig. 9)
  autotune— TUNE-parameter search convergence           (§II, Table I)
  pipeline— combined cache+scheduler+channels config    (Fig. 7 composed)

The paper-claim probes (fig7 / fig7w / pipeline) also persist
machine-readable ``BENCH_fig7.json`` / ``BENCH_fig7_write.json`` /
``BENCH_pipeline.json`` summaries so the repo's perf trajectory
accumulates per PR (the pipeline probe runs at full size so the
tracked artifact stays stable; CI smoke uses ``--small``);
``benchmarks/perf_trace_engine.py`` (run separately — it is
minutes-long at full size) writes ``BENCH_trace_engine.json`` for the
simulator's own throughput, ``benchmarks/perf_channels.py`` (also
separate) writes ``BENCH_channels.json`` for the multi-channel /
multi-port front end, and ``benchmarks/perf_dram_sched.py`` (also
separate) writes ``BENCH_dram_sched.json`` for the out-of-order DRAM
command scheduler sweep.
"""

from benchmarks import (autotune_bench, fig5_dma_resources,
                        fig6_scheduler_cost, fig7_workloads,
                        fig7_write_workloads, fig8_interface_width,
                        fig9_schedule_time, perf_pipeline,
                        table3_cache_resources)
from benchmarks.common import write_bench_json


def main() -> None:
    print("name,us_per_call,derived")
    table3_cache_resources.run()
    fig5_dma_resources.run()
    fig6_scheduler_cost.run()
    write_bench_json("fig7", fig7_workloads.run())
    write_bench_json("fig7_write", fig7_write_workloads.run())
    fig8_interface_width.run()
    fig9_schedule_time.run()
    autotune_bench.run()
    # Full size, so the tracked BENCH_pipeline.json acceptance artifact
    # is never overwritten with CI-size numbers (CI runs --small).
    perf_pipeline.run()            # writes BENCH_pipeline.json itself


if __name__ == "__main__":
    main()
