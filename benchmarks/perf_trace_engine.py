"""Trace-engine throughput: requests/second, old (sequential) vs new
(set-parallel / vectorized) measurement substrate.

This is the simulator-performance benchmark the ROADMAP's "as fast as the
hardware allows" goal demands of the *measurement substrate itself*: the
paper-claim reproductions (Fig. 7/8/9) simulate request traces, and
graph/CNN-sized workloads need 10⁶–10⁷ requests. Two synthetic 1M-request
mixed read/write traces are pushed through the two hot stages of the
reproduction pipeline:

  modeled_access_time — dual-queue batch formation + per-batch row sort
        + cycle-level DRAM simulation (``MemoryController`` entry point);
        old = ``schedule_trace_rw_seq`` (request-at-a-time python),
        new = vectorized planner + one lexsort.
  simulate_trace_rw   — the cache engine serving the trace beat-accurately;
        old = one ``lax.scan`` step per request,
        new = set-parallel tag pipeline + vectorized value reconstruction
        (bit-identical results, see ``core/trace_engine.py``).

Traces:

  gcn_style — Zipf-popular vertices (graph adjacency / embedding rows),
        8 cache lines per vertex row, ~50/50 read-modify-write mix: the
        skewed irregular stream of the Fig. 7 GCN workload at million-edge
        scale.
  cnn_style — sliding-window line re-reads (conv input rows) with periodic
        activation write-backs: high spatial locality, mostly reads.

By default both old and new paths run the *full* trace (the old cache
scan takes ~7 s/M requests — the point of this benchmark); ``--small``
(≈50k requests, sequential paths capped at a sample and compared by
rate) is the CI perf-smoke configuration. Writes
``BENCH_trace_engine.json`` (see README) with per-stage and end-to-end
pipeline speedups.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.core.cache_engine import (hit_rate_oracle, hit_rate_oracle_seq,
                                     init_cache, simulate_trace_rw,
                                     simulate_trace_rw_seq)
from repro.core.config import CacheConfig, PAPER_EVAL_CONFIG
from repro.core.controller import MemoryController
from repro.core.scheduler import schedule_trace_rw_seq
from repro.core.timing import simulate_dram_access

LINE_ELEMS = 4          # modeled payload elements per cache line
ROW_BYTES = 4096


def gcn_style_trace(rng, n, n_rows):
    """Zipf-hot vertex rows (α=1.1, the classic hot-key regime — the
    most popular vertex draws ~9% of edge visits), 8 cache lines per
    4 KiB feature row, mixed read/write."""
    verts = (rng.zipf(1.1, n) - 1) % (n_rows // 8)
    lids = verts * 8 + rng.integers(0, 8, n)
    rw = rng.integers(0, 2, n)
    return lids.astype(np.int64), rw.astype(np.int32)


def cnn_style_trace(rng, n, n_rows):
    """Sliding conv windows: each line re-read ~4x with stride-1 overlap,
    one activation write-back every 8 requests."""
    sweep = (np.arange(n) // 4) % (n_rows - 8)
    lids = sweep + rng.integers(0, 8, n)
    rw = (np.arange(n) % 8 == 7).astype(np.int32)
    return lids.astype(np.int64), rw


def _timed(fn, reps: int = 2):
    """Best wall time of ``reps`` runs (the first call was already made
    by the caller to warm compile caches)."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_workload(name, lids, rw, *, seq_sample, results):
    n = lids.shape[0]
    n_rows = int(lids.max()) + 8
    rng = np.random.default_rng(1)
    cfg = PAPER_EVAL_CONFIG
    cache_cfg = CacheConfig(num_lines=4096, associativity=4)
    mc = MemoryController(cfg)

    table = jnp.asarray(rng.standard_normal((n_rows, LINE_ELEMS)),
                        jnp.float32)
    wl = jnp.asarray(rng.standard_normal((n, LINE_ELEMS)), jnp.float32)
    lids_j = jnp.asarray(lids, jnp.int32)
    rw_j = jnp.asarray(rw, jnp.int32)
    state = init_cache(cache_cfg, LINE_ELEMS)
    ns = min(seq_sample, n)

    # --- stage 1: modeled access time (scheduler + DRAM simulator) -------
    def modeled_old():
        served, served_rw = schedule_trace_rw_seq(
            lids[:ns] * ROW_BYTES, rw[:ns], config=cfg.scheduler,
            timings=mc.timings, coalesce_writes=True)
        return simulate_dram_access(served, mc.timings, rw=served_rw
                                    ).total_fpga_cycles

    def modeled_new():
        return mc.modeled_access_time(lids, rw, ROW_BYTES,
                                      coalesce_writes=True
                                      ).total_fpga_cycles

    t_mod_old = _timed(modeled_old)
    modeled_new()                                    # warm compile caches
    t_mod_new = _timed(modeled_new)

    # --- stage 2: cache engine trace service -----------------------------
    def cache_old():
        return simulate_trace_rw_seq(state, lids_j[:ns], rw_j[:ns],
                                     wl[:ns], table, config=cache_cfg)

    def cache_new():
        return simulate_trace_rw(state, lids_j, rw_j, wl, table,
                                 config=cache_cfg, engine="parallel")

    cache_old()                                      # warm compile caches
    t_cache_old = _timed(cache_old)
    cache_new()
    t_cache_new = _timed(cache_new)

    # --- side oracle: numpy hit-rate LRU ---------------------------------
    t_oracle_old = _timed(lambda: hit_rate_oracle_seq(cache_cfg, lids[:ns]))
    t_oracle_new = _timed(lambda: hit_rate_oracle(cache_cfg, lids))

    def rates(t_old, t_new):
        old_rps = ns / t_old
        new_rps = n / t_new
        return {"old_rps": round(old_rps), "new_rps": round(new_rps),
                "old_seconds": round(t_old, 4),
                "new_seconds": round(t_new, 4),
                "speedup": round(new_rps / old_rps, 2)}

    pipeline = {
        "old_rps": round(ns / (t_mod_old + t_cache_old)),
        "new_rps": round(n / (t_mod_new + t_cache_new)),
        "speedup": round((n / (t_mod_new + t_cache_new))
                         / (ns / (t_mod_old + t_cache_old)), 2),
    }
    oracle_rates = rates(t_oracle_old, t_oracle_new)
    # The compacted-lane oracle must never lose to the sequential walk
    # (the pre-compaction GCN regression was 0.97x — pinned here).
    assert oracle_rates["speedup"] >= 1.0, (
        f"{name}: hit_rate_oracle slower than the sequential oracle "
        f"({oracle_rates['speedup']}x)")
    results["workloads"][name] = {
        "modeled_access_time": rates(t_mod_old, t_mod_new),
        "simulate_trace_rw": rates(t_cache_old, t_cache_new),
        "hit_rate_oracle": oracle_rates,
        "pipeline": pipeline,
    }
    emit(f"perf_trace_engine/{name}",
         (t_mod_new + t_cache_new) * 1e6,
         f"pipeline_speedup={pipeline['speedup']}x|"
         f"new_rps={pipeline['new_rps']}|old_rps={pipeline['old_rps']}|"
         f"cache_speedup={results['workloads'][name]['simulate_trace_rw']['speedup']}x|"
         f"sched_speedup={results['workloads'][name]['modeled_access_time']['speedup']}x")


def run(n_requests: int = 1_000_000,
        seq_sample: int | None = None) -> dict:
    rng = np.random.default_rng(0)
    n_rows = 65536
    seq_sample = n_requests if seq_sample is None else min(seq_sample,
                                                           n_requests)
    results = {
        "benchmark": "trace_engine_throughput",
        "unit": "requests_per_second",
        "n_requests": n_requests,
        "seq_sample": seq_sample,
        "note": ("old_* = seed sequential paths (request-at-a-time) on "
                 "seq_sample requests; new_* = set-parallel / vectorized "
                 "paths on the full trace; rates compared. Outputs are "
                 "bit-identical (see tests/core/test_trace_engine_equiv"
                 ".py)."),
        "workloads": {},
    }
    for name, maker in (("gcn_style", gcn_style_trace),
                        ("cnn_style", cnn_style_trace)):
        lids, rw = maker(rng, n_requests, n_rows)
        bench_workload(name, lids, rw, seq_sample=results["seq_sample"],
                       results=results)
    write_bench_json("trace_engine", results)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--small", action="store_true",
                    help="CI perf-smoke size (~50k requests)")
    ap.add_argument("--n", type=int, default=None,
                    help="override trace length")
    args = ap.parse_args()
    n = args.n or (50_000 if args.small else 1_000_000)
    seq_sample = min(20_000, n) if args.small else None   # None = full
    print("name,us_per_call,derived")
    run(n, seq_sample)


if __name__ == "__main__":
    main()
