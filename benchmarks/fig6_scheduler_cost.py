"""Fig. 6 + Eq. 1 — scheduler cost vs batch size.

FPGA LUT/FF grows ~3x per batch-size doubling (spatial comparators); the
TPU network instead grows the *stage count* as log2(N)(log2(N)+1)/2 with a
constant VMEM footprint per element (the lanes-normalized adaptation noted
in DESIGN.md §2). Reports Eq. 1 cycles and the measured bitonic-kernel
sort time per batch size; derived field carries both.
"""

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.config import scheduler_sort_stages
from repro.core.timing import t_schedule
from repro.kernels.bitonic_sort import ops


def run() -> None:
    rng = np.random.default_rng(0)
    for batch in (4, 8, 16, 32, 64, 128, 256, 512):
        keys = jnp.asarray(rng.integers(0, 1 << 20, batch), jnp.int32)
        us = time_call(lambda k=keys: ops.sort_with_indices(k), iters=3,
                       warmup=1)
        vmem_bytes = 2 * batch * 8
        emit(f"fig6/batch{batch}", us,
             f"eq1_cycles={t_schedule(batch):.0f}|"
             f"stages={scheduler_sort_stages(batch)}|"
             f"vmem={vmem_bytes}B")


if __name__ == "__main__":
    run()
