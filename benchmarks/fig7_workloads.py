"""Fig. 7 — GCN / CNN memory access time: controller vs commercial baseline.

Methodology (paper §V-A/§V-C, hardware replaced by the cycle-level DDR4
simulator per DESIGN.md §8): synthetic traces reflective of each workload's
published access pattern are serviced two ways —

  baseline   : requests hit the memory interface FIFO, in arrival order,
               no reordering, no on-chip cache (commercial IP + direct
               accelerator connection);
  controller : cache engine absorbs re-usable structures, the scheduler
               batch-reorders misses by row, the DMA engine streams bulk
               transfers on parallel channels (Table IV configuration).

Claims validated: GCN total access time -27%, DMA-dominant (99%);
CNN -58%, DMA ~80% of time; see derived fields.

GCN trace  — synthetic graph per the paper (scaled 1:1000 for runtime:
1.6K vertices / 240K edge visits, 1024 features -> 4 KiB feature rows):
adjacency reads are cacheable (Zipf-popular vertices), feature vectors are
bulk DMA reads at random vertex addresses.

CNN trace  — ResNet input layer on 227x227 images: kernel weights are tiny
re-used rows (cache), input rows are streamed bulk reads (DMA).
"""

import dataclasses
import time

import numpy as np

from benchmarks.common import emit
from repro.core.cache_engine import hit_rate_oracle
from repro.core.config import PAPER_EVAL_CONFIG
from repro.core.dma_engine import modeled_transfer_cycles, plan_transfer
from repro.core.scheduler import schedule_trace
from repro.core.timing import (DDR4_2400, simulate_dram_access,
                               simulate_dram_access_windowed)

NUM_PES = 8          # concurrent PE request streams at the interface


def _interleave(streams):
    """Round-robin interleave request streams (parallel PEs)."""
    maxlen = max(len(s) for s in streams)
    out = []
    for i in range(maxlen):
        for s in streams:
            if i < len(s):
                out.append(s[i])
    return np.asarray(out, np.int64)


def gcn_trace(rng):
    n_vertices = 1600
    n_edges = 240_000 // 4          # edge visits sampled
    feat_bytes = 4096               # 1024 features x 4B
    adj_bytes = 256
    feat_base = 1 << 26
    # adjacency reads: Zipf-popular vertices (reusable across PEs)
    adj_v = (rng.zipf(1.2, n_edges) - 1) % n_vertices
    adj_addrs = adj_v * adj_bytes
    # feature fetches: destination vertices of edges (random)
    feat_v = rng.integers(0, n_vertices, n_edges // 16)
    feat_addrs = feat_base + feat_v * feat_bytes
    return adj_addrs, feat_addrs, feat_bytes


def cnn_trace(rng):
    """ResNet input layer, 227x227 images (paper §V-C): the *cache engine*
    serves image-window reads (sliding 7x7 conv windows re-read
    overlapping lines) and the *DMA engine* streams kernel weights."""
    img, k, stride = 227, 7, 2
    row_bytes = img * 4             # one image row, one channel
    img_base = 0
    w_base = 1 << 26
    w_transfer = 16 * 1024          # filter-bank stream per output tile
    cache_reqs = []
    for y in range(0, img - k, stride):         # full output grid
        for x in range(0, img - k, stride):
            for ky in range(k):                 # one line read per kernel row
                cache_reqs.append((y + ky) * row_bytes + x * 4)
    cache_addrs = np.asarray(cache_reqs, np.int64)
    n_tiles = 220                               # filter re-streams
    w_addrs = w_base + (np.arange(n_tiles) % 8) * w_transfer
    return cache_addrs, w_addrs, w_transfer


def run_workload(name, cache_addrs, bulk_addrs, bulk_bytes):
    cfg = PAPER_EVAL_CONFIG
    t = DDR4_2400

    # ---------- baseline: NUM_PES streams through the commercial IP -------
    # Each PE issues its bulk reads as interface-width bursts; the cache-
    # class requests share the interface. The IP services them with a
    # shallow greedy reorder window (MIG-like), not the controller's
    # batch-wide bitonic reorder.
    bulk_expanded = [a + np.arange(0, bulk_bytes, 64) for a in bulk_addrs]
    streams = []
    for pe in range(NUM_PES - 1):
        streams.append(np.concatenate(bulk_expanded[pe::NUM_PES - 1])
                       if bulk_expanded[pe::NUM_PES - 1] else
                       np.empty(0, np.int64))
    streams.append(cache_addrs)
    base_stream = _interleave(streams)
    t0 = time.perf_counter()
    # two baseline strengths: pure FIFO, and MIG-like shallow reorder —
    # the paper's "up to" improvement corresponds to the weaker baseline
    base_fifo = simulate_dram_access_windowed(base_stream, t,
                                              window=1).total_fpga_cycles
    base = simulate_dram_access_windowed(base_stream, t,
                                         window=4).total_fpga_cycles
    sim_us = (time.perf_counter() - t0) * 1e6

    # ---------- controller (same DRAM simulator, different ordering) ------
    # cache engine absorbs the re-usable rows; misses are batch-reordered
    line_ids = cache_addrs // cfg.cache.line_bytes
    hits, hit_rate = hit_rate_oracle(cfg.cache, line_ids)
    misses = cache_addrs[~hits]
    served = schedule_trace(misses, np.zeros(len(misses), np.int32),
                            config=cfg.scheduler, timings=t)
    cache_cycles = (simulate_dram_access(served, t).total_fpga_cycles
                    + hits.sum() * 1.0 + cfg.ctrl_overhead_cycles)
    # DMA engine: whole transfers stream back-to-back at the DRAM (the
    # channels overlap controller-side latency, not DRAM occupancy), and
    # bulk traffic is never interleaved with cache traffic (the
    # cache-priority/stall rule of §IV).
    dma_cycles = simulate_dram_access(
        np.concatenate(bulk_expanded) if bulk_expanded
        else np.empty(0, np.int64), t).total_fpga_cycles
    ctrl = cache_cycles + dma_cycles

    improvement = 1 - ctrl / base
    improvement_fifo = 1 - ctrl / base_fifo
    emit(f"fig7/{name}", sim_us,
         f"improvement_vs_mig={improvement:.1%}|"
         f"improvement_vs_fifo={improvement_fifo:.1%}|"
         f"controller_cycles={ctrl:.0f}|cache_hit={hit_rate:.2f}|"
         f"dma_share={dma_cycles / ctrl:.0%}")
    return {
        "improvement_vs_mig": round(improvement, 4),
        "improvement_vs_fifo": round(improvement_fifo, 4),
        "controller_cycles": round(ctrl),
        "baseline_mig_cycles": round(base),
        "baseline_fifo_cycles": round(base_fifo),
        "cache_hit_rate": round(hit_rate, 4),
        "dma_share": round(dma_cycles / ctrl, 4),
    }


def run() -> dict:
    """Returns per-workload modeled-improvement records; the runner
    persists them as BENCH_fig7.json."""
    rng = np.random.default_rng(0)
    adj, feat, fb = gcn_trace(rng)
    gcn = run_workload("gcn_inference", adj, feat, fb)
    w, inp, ib = cnn_trace(rng)
    cnn = run_workload("cnn_inference", w, inp, ib)
    return {"benchmark": "fig7_modeled_access_time",
            "paper_claim": {"gcn_inference": 0.27, "cnn_inference": 0.58},
            "workloads": {"gcn_inference": gcn, "cnn_inference": cnn}}


if __name__ == "__main__":
    run()
