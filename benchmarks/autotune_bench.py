"""§II / Table I — the programmability payoff: autotuning TUNE parameters.

Sweeps the TUNE grid for two access patterns (GCN-like irregular zipf,
CNN-like strided) and reports the best configuration + its modeled win
over the PAPER_EVAL_CONFIG default — what an end-user gets from the
parameterized IP that a fixed commercial controller cannot offer.
"""

import time

import numpy as np

from benchmarks.common import emit
from repro.core.autotune import tune, _score
from repro.core.config import PAPER_EVAL_CONFIG
from repro.core.timing import DDR4_2400


def run() -> None:
    rng = np.random.default_rng(0)
    workloads = {
        "gcn_like": (rng.zipf(1.2, 4096) - 1) % 2048,
        "cnn_like": np.repeat(np.arange(512), 8)[rng.permutation(4096)],
    }
    for name, rows in workloads.items():
        t0 = time.perf_counter()
        res = tune(rows, 512, vmem_budget_bytes=8 << 20,
                   batch_sizes=(16, 64, 256),
                   associativities=(1, 4), num_lines=(1024, 4096),
                   dma_channels=(2,))
        us = (time.perf_counter() - t0) * 1e6
        default_cycles = _score(PAPER_EVAL_CONFIG, rows, 512, DDR4_2400)
        win = 1 - res.modeled_cycles / default_cycles
        c = res.config
        emit(f"autotune/{name}", us,
             f"best=batch{c.scheduler.batch_size}_ways"
             f"{c.cache.associativity}_lines{c.cache.num_lines}|"
             f"vs_default={win:+.1%}|evaluated={res.candidates_evaluated}")


if __name__ == "__main__":
    run()
