"""DRAM command-scheduler benchmark — policy x reorder-window sweep on
the GCN/CNN traces (the "Memory Controller Wall" experiment: how much
of the naive-interface gap does a bounded reorder window recover?).

Every configuration runs the engines-off controller
(``MemoryController.simulate`` with batch scheduler and cache disabled)
so the *only* difference between rows is the DRAM command scheduler:

  fifo        — strict arrival-order issue (the pre-PR service model);
  frfcfs      — oldest-row-ready-first within a ``reorder_window``;
  frfcfs_cap  — FR-FCFS with ``starvation_cap=16`` slip bound;
  + a DDR4-realistic refresh row (tRFC 350ns / tREFI 7.8us in command
    clocks) showing the refresh tax on the best window.

Acceptance (ISSUE 5), recorded machine-readably:

* ``frfcfs_w8_beats_fifo_gcn`` — FR-FCFS at window >= 8 strictly beats
  the FIFO makespan on the GCN trace;
* ``window1_bit_identical`` — window=1 reproduces the pre-PR simulators
  bit for bit (both the pipeline makespan vs the FIFO config and the
  raw classifier vs ``simulate_dram_access_windowed(window=1)``).

The JSON also carries the combined-configuration row (cache + batch
scheduler + channels + FR-FCFS) and the fast-path-vs-oracle speedup of
the simulator itself. Writes ``BENCH_dram_sched.json``; ``--small``
(~50k requests) is the CI perf-smoke configuration.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from benchmarks.perf_pipeline import (ROW_BYTES, cnn_style_trace,
                                      gcn_style_trace)
from repro.core.config import (CacheConfig, DRAMSchedConfig,
                               MemoryControllerConfig,
                               PAPER_COMBINED_CONFIG, SchedulerConfig)
from repro.core.controller import MemoryController
from repro.core.timing import (DDR4_2400, simulate_dram_access_windowed,
                               simulate_dram_sched,
                               simulate_dram_sched_seq)

WINDOWS = (1, 4, 8, 16, 32, 64)
# DDR4-2400 8Gb refresh in command clocks: tRFC ~350ns, tREFI ~7.8us
T_RFC, T_REFI = 420, 9363

BARE = MemoryControllerConfig(
    scheduler=SchedulerConfig(enabled=False),
    cache=CacheConfig(enabled=False))


def _with_sched(base: MemoryControllerConfig,
                **kw) -> MemoryControllerConfig:
    return dataclasses.replace(base, dram_sched=DRAMSchedConfig(**kw))


def _makespan(cfg, rows, rw) -> tuple[float, float]:
    mc = MemoryController(cfg)
    t0 = time.perf_counter()
    res = mc.simulate(None, rows, rw, ROW_BYTES)
    return res.makespan_fpga_cycles, (time.perf_counter() - t0) * 1e6


def run(n_requests: int = 200_000) -> dict:
    rng = np.random.default_rng(0)
    traces = {
        "gcn_style": gcn_style_trace(rng, n_requests),
        "cnn_style": cnn_style_trace(rng, n_requests),
    }
    results: dict = {
        "benchmark": "dram_command_scheduler_sweep",
        "unit": "modeled_fpga_cycles",
        "n_requests": n_requests,
        "row_bytes": ROW_BYTES,
        "windows": list(WINDOWS),
        "refresh_model": {"t_rfc": T_RFC, "t_refi": T_REFI},
        "note": ("engines-off controller isolates the DRAM command "
                 "scheduler; window=1 and policy=fifo are bit-identical "
                 "to the pre-PR FIFO service (tests/core/"
                 "test_dram_sched.py pins this per request)"),
        "workloads": {},
    }
    fifo_raw: dict[str, float] = {}
    for tname, (rows, rw) in traces.items():
        rec: dict = {"fifo": {}, "frfcfs": {}, "frfcfs_cap": {}}
        fifo_ms, dt = _makespan(BARE, rows, rw)
        fifo_raw[tname] = fifo_ms
        rec["fifo"]["1"] = round(fifo_ms)
        emit(f"perf_dram_sched/{tname}/fifo_w1", dt,
             f"makespan={round(fifo_ms)}")
        for policy in ("frfcfs", "frfcfs_cap"):
            for w in WINDOWS[1:]:
                ms, dt = _makespan(
                    _with_sched(BARE, policy=policy, reorder_window=w,
                                starvation_cap=16), rows, rw)
                rec[policy][str(w)] = round(ms)
                emit(f"perf_dram_sched/{tname}/{policy}_w{w}", dt,
                     f"makespan={round(ms)}|"
                     f"speedup_vs_fifo={fifo_ms / ms:.3f}x")
        # refresh tax on the best frfcfs window
        best_w = min(rec["frfcfs"], key=lambda k: rec["frfcfs"][k])
        ms_ref, dt = _makespan(
            _with_sched(BARE, policy="frfcfs",
                        reorder_window=int(best_w),
                        t_rfc=T_RFC, t_refi=T_REFI), rows, rw)
        rec["frfcfs_refresh"] = {best_w: round(ms_ref)}
        emit(f"perf_dram_sched/{tname}/frfcfs_w{best_w}_refresh", dt,
             f"makespan={round(ms_ref)}|"
             f"refresh_tax={ms_ref / rec['frfcfs'][best_w]:.4f}x")
        rec["speedup_vs_fifo_at_w8"] = round(
            fifo_ms / rec["frfcfs"]["8"], 4)
        results["workloads"][tname] = rec

    # ---- acceptance records ------------------------------------------
    g = results["workloads"]["gcn_style"]
    results["frfcfs_w8_beats_fifo_gcn"] = bool(
        all(g["frfcfs"][str(w)] < g["fifo"]["1"] for w in (8, 16, 32, 64)))

    rows, rw = traces["gcn_style"]
    sub = rows[:20_000]
    w1_pipeline, _ = _makespan(
        _with_sched(BARE, policy="frfcfs", reorder_window=1), rows, rw)
    raw_w1 = simulate_dram_sched(
        sub * ROW_BYTES, DDR4_2400,
        DRAMSchedConfig(policy="frfcfs", reorder_window=1))
    raw_old = simulate_dram_access_windowed(sub * ROW_BYTES, DDR4_2400,
                                            window=1)
    results["window1_bit_identical"] = bool(
        w1_pipeline == fifo_raw["gcn_style"]
        and raw_w1.total_fpga_cycles == raw_old.total_fpga_cycles
        and (raw_w1.row_hits, raw_w1.row_conflicts,
             raw_w1.first_accesses) == (raw_old.row_hits,
                                        raw_old.row_conflicts,
                                        raw_old.first_accesses))

    # combined headline config with and without FR-FCFS service
    comb_rec = {}
    for label, cfg in (
            ("fifo", PAPER_COMBINED_CONFIG),
            ("frfcfs16", _with_sched(PAPER_COMBINED_CONFIG,
                                     policy="frfcfs",
                                     reorder_window=16))):
        ms, dt = _makespan(cfg, rows, rw)
        comb_rec[label] = round(ms)
        emit(f"perf_dram_sched/gcn_style/combined_{label}", dt,
             f"makespan={round(ms)}")
    comb_rec["frfcfs_helps_combined"] = bool(
        comb_rec["frfcfs16"] < comb_rec["fifo"])
    results["combined_config"] = comb_rec

    # simulator-throughput record: fast path vs request-at-a-time oracle
    sched = DRAMSchedConfig(policy="frfcfs", reorder_window=32)
    n_perf = min(20_000, rows.shape[0])
    addrs = rows[:n_perf] * ROW_BYTES
    t0 = time.perf_counter()
    fast = simulate_dram_sched(addrs, DDR4_2400, sched, rw[:n_perf])
    t_fast = time.perf_counter() - t0
    t0 = time.perf_counter()
    seq = simulate_dram_sched_seq(addrs, DDR4_2400, sched, rw[:n_perf])
    t_seq = time.perf_counter() - t0
    assert fast.total_fpga_cycles == seq.total_fpga_cycles
    results["fast_path_speedup_vs_oracle_w32"] = round(t_seq / t_fast, 2)
    emit("perf_dram_sched/fast_vs_oracle", t_fast * 1e6,
         f"speedup={t_seq / t_fast:.1f}x|n={n_perf}")

    write_bench_json("dram_sched", results)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--small", action="store_true",
                    help="CI perf-smoke size (~50k requests)")
    ap.add_argument("--n", type=int, default=None,
                    help="override trace length")
    args = ap.parse_args()
    n = args.n or (50_000 if args.small else 200_000)
    print("name,us_per_call,derived")
    run(n)


if __name__ == "__main__":
    main()
