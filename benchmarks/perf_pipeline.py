"""Combined-configuration pipeline benchmark — cache + scheduler +
channels composed, on the CNN- and GCN-style traces (the paper's
headline Fig. 7 setting, reproduced end-to-end through ONE staged
simulator instead of per-engine oracles).

For each trace the same request stream runs through four controller
configurations of ``MemoryController.simulate()``:

  baseline_fifo   — every engine off, single channel (commercial-IP
                    in-order service; the Fig. 7 baseline strength);
  scheduler_only  — batch scheduler on, cache off, 1 and 4 channels;
  cache_only      — cache filter on, scheduler off, 4 channels;
  combined        — PAPER_COMBINED_CONFIG: cache + scheduler + 4-channel
                    front end (+ the 8-PE arbiters for the multiport
                    record).

Acceptance (ISSUE 4): the combined configuration beats the
scheduler-only modeled latency on BOTH traces — recorded machine-
readably as ``combined_beats_scheduler_only``. The JSON also carries the
per-stage cycle breakdown of the combined run (the PipelineResult view
of the paper's Fig. 7 methodology).

Writes ``BENCH_pipeline.json``; ``--small`` (~50k requests) is the CI
perf-smoke configuration.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.core.config import (CacheConfig, ChannelConfig,
                               MemoryControllerConfig,
                               PAPER_COMBINED_CONFIG, SchedulerConfig)
from repro.core.controller import MemoryController

ROW_BYTES = 4096


def gcn_style_trace(rng, n):
    """Paper-faithful GCN inference stream (Fig. 7a): Zipf-popular
    adjacency/feature rows over a bounded vertex set (the cacheable
    re-usable structure of §III) with ~10% aggregation write-backs —
    unlike perf_channels' cache-hostile variant, reuse here is real,
    which is exactly what the combined configuration exploits."""
    rows = (rng.zipf(1.2, n) - 1) % 8192
    rw = (rng.random(n) < 0.1).astype(np.int32)
    return rows.astype(np.int64), rw


def cnn_style_trace(rng, n):
    """ResNet-style sliding conv windows (overlapping row re-reads) with
    periodic activation write-backs — the Fig. 7b access shape."""
    n_rows = 1 << 14
    sweep = (np.arange(n) // 4) % (n_rows - 8)
    rows = (sweep + rng.integers(0, 8, n)).astype(np.int64)
    rw = (np.arange(n) % 8 == 7).astype(np.int32)
    return rows, rw


def _configs() -> dict[str, MemoryControllerConfig]:
    return {
        "baseline_fifo": MemoryControllerConfig(
            scheduler=SchedulerConfig(enabled=False),
            cache=CacheConfig(enabled=False)),
        "scheduler_only_1ch": MemoryControllerConfig(
            cache=CacheConfig(enabled=False)),
        "scheduler_only_4ch": MemoryControllerConfig(
            cache=CacheConfig(enabled=False),
            channels=ChannelConfig(num_channels=4)),
        "cache_only_4ch": MemoryControllerConfig(
            scheduler=SchedulerConfig(enabled=False),
            channels=ChannelConfig(num_channels=4)),
        "combined": PAPER_COMBINED_CONFIG,
    }


def _record(res) -> dict:
    return {
        "makespan_fpga_cycles": round(res.makespan_fpga_cycles),
        "dram_makespan_fpga_cycles": round(res.dram_makespan_fpga_cycles),
        "cache_hit_rate": (None if res.cache_hit_rate is None
                           else round(res.cache_hit_rate, 4)),
        "breakdown": {k: round(v, 1) for k, v in res.breakdown().items()},
    }


def run(n_requests: int = 200_000) -> dict:
    rng = np.random.default_rng(0)
    traces = {
        "gcn_style": gcn_style_trace(rng, n_requests),
        "cnn_style": cnn_style_trace(rng, n_requests),
    }
    results: dict = {
        "benchmark": "pipeline_combined_configuration",
        "unit": "modeled_fpga_cycles",
        "n_requests": n_requests,
        "row_bytes": ROW_BYTES,
        "note": ("one staged simulator (repro.core.pipeline) produces "
                 "every number; legacy entry points are stage subsets, "
                 "bit-identical to pre-refactor outputs "
                 "(tests/core/test_pipeline.py)"),
        "workloads": {},
    }
    ok_all = True
    for tname, (rows, rw) in traces.items():
        rec: dict = {}
        for cname, cfg in _configs().items():
            mc = MemoryController(cfg)
            t0 = time.perf_counter()
            res = mc.simulate(None, rows, rw, ROW_BYTES)
            dt = (time.perf_counter() - t0) * 1e6
            rec[cname] = _record(res)
            emit(f"perf_pipeline/{tname}/{cname}", dt,
                 f"makespan={rec[cname]['makespan_fpga_cycles']}|"
                 f"hit_rate={rec[cname]['cache_hit_rate']}")
        # multiport record: 8 PEs contending through the combined config
        pe = rng.integers(0, 8, rows.shape[0])
        mp = MemoryController(PAPER_COMBINED_CONFIG).simulate(
            pe, rows, rw, ROW_BYTES)
        rec["combined_multiport_8pe"] = dict(
            _record(mp),
            fairness=round(mp.port_stats.fairness, 4),
            arbitration_cycles=mp.arbitration_cycles)
        beats = {
            "vs_1ch": (rec["combined"]["makespan_fpga_cycles"]
                       < rec["scheduler_only_1ch"]["makespan_fpga_cycles"]),
            "vs_4ch": (rec["combined"]["makespan_fpga_cycles"]
                       < rec["scheduler_only_4ch"]["makespan_fpga_cycles"]),
        }
        rec["combined_beats_scheduler_only"] = beats
        ok_all &= beats["vs_1ch"] and beats["vs_4ch"]
        speedup = (rec["scheduler_only_4ch"]["makespan_fpga_cycles"]
                   / max(1, rec["combined"]["makespan_fpga_cycles"]))
        rec["combined_speedup_vs_scheduler_only_4ch"] = round(speedup, 3)
        emit(f"perf_pipeline/{tname}/acceptance", 0.0,
             f"combined_beats_scheduler_only={beats['vs_4ch']}|"
             f"speedup_vs_sched4ch={speedup:.2f}x")
        results["workloads"][tname] = rec
    results["combined_beats_scheduler_only_all"] = bool(ok_all)
    # machine-checkable refactor record: one legacy entry point vs its
    # pipeline subset on a shared sample (bit-identity beyond the tests)
    rows = traces["gcn_style"][0][:20_000]
    rw = traces["gcn_style"][1][:20_000]
    mc = MemoryController(_configs()["scheduler_only_4ch"])
    legacy = mc.modeled_access_time(rows, rw, ROW_BYTES)
    subset = mc.simulate(None, rows, rw, ROW_BYTES).as_sim_result()
    results["legacy_entry_point_bit_identical"] = \
        dataclasses.asdict(legacy) == dataclasses.asdict(subset)
    write_bench_json("pipeline", results)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--small", action="store_true",
                    help="CI perf-smoke size (~50k requests)")
    ap.add_argument("--n", type=int, default=None,
                    help="override trace length")
    args = ap.parse_args()
    n = args.n or (50_000 if args.small else 200_000)
    print("name,us_per_call,derived")
    run(n)


if __name__ == "__main__":
    main()
