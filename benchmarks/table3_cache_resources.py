"""Table III — cache resource utilization vs reconfigurable parameters.

FPGA URAM/BRAM% maps to the VMEM working set on TPU (v5e: 128 MiB VMEM per
chip as the '100%' denominator). Reproduces the paper's finding that
storage scales linearly with line width x line count x associativity while
logic (here: tag/LRU metadata) stays small. ``us_per_call`` times one
lookup batch through the cache engine at that geometry.
"""

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.cache_engine import init_cache, simulate_trace
from repro.core.config import CacheConfig

VMEM_BYTES = 128 * 1024 * 1024   # v5e VMEM per chip

# (line_width_bits, ways, num_lines) — the Table III rows
ROWS = [
    (512, 1, 512), (512, 1, 1024), (512, 1, 4096),
    (512, 2, 2048), (512, 2, 8192),
    (1024, 2, 8192), (2048, 2, 8192), (4096, 2, 8192),
    (512, 4, 4096), (512, 4, 16384),
    (512, 8, 8192), (512, 8, 32768),
]


def run() -> None:
    rng = np.random.default_rng(0)
    for width, ways, lines in ROWS:
        cfg = CacheConfig(line_width_bits=width, num_lines=lines,
                          associativity=ways)
        data_pct = 100 * cfg.capacity_bytes / VMEM_BYTES
        meta_pct = 100 * (8 * cfg.num_lines) / VMEM_BYTES
        line_elems = cfg.line_bytes // 4
        state = init_cache(cfg, line_elems)
        table = jnp.zeros((lines * 2, line_elems), jnp.float32)
        lids = jnp.asarray(rng.integers(0, lines * 2, 64), jnp.int32)
        us = time_call(lambda: simulate_trace(state, lids, table), iters=3,
                       warmup=1)
        emit(f"table3/line{width}b_ways{ways}_n{lines}", us,
             f"vmem_data={data_pct:.2f}%|vmem_meta={meta_pct:.3f}%")


if __name__ == "__main__":
    run()
