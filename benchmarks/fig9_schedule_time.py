"""Fig. 9 — schedule-time breakdown vs batch size.

Two series per batch size N on a random-row request trace:
  * batch formation time — Eq. 1 for the *first* batch (later batch
    formation overlaps DRAM service of the previous batch, double-buffered
    input queues);
  * total time — first-batch formation + DRAM service of the reordered
    stream + any residual (non-overlapped) scheduling.

Claim: total time falls with N until scheduling overhead dominates;
N = 32-64 is the sweet spot under modest resource use (paper §V-C).
``us_per_call`` times the end-to-end schedule_trace+simulate pipeline.
"""

import time

import numpy as np

from benchmarks.common import emit
from repro.core.config import SchedulerConfig
from repro.core.scheduler import schedule_trace
from repro.core.timing import DDR4_2400, simulate_dram_access, t_schedule

TRACE = 8192
ROWS = 48          # row working set: enough duplicates for reordering to pay


def run() -> None:
    rng = np.random.default_rng(0)
    addrs = rng.integers(0, ROWS, TRACE) * DDR4_2400.row_bytes
    rw = np.zeros(TRACE, np.int32)
    base = simulate_dram_access(addrs).total_fpga_cycles

    results, efficiency = {}, {}
    for batch in (4, 8, 16, 32, 64, 128, 256, 512):
        cfg = SchedulerConfig(batch_size=batch, bypass_sequential=False)
        t0 = time.perf_counter()
        served = schedule_trace(addrs, rw, config=cfg)
        dram = simulate_dram_access(served).total_fpga_cycles
        us = (time.perf_counter() - t0) * 1e6
        n_batches = TRACE // batch
        form_first = t_schedule(batch)
        # residual per batch: scheduling not hidden behind DRAM service
        resid = max(0.0, t_schedule(batch) - dram / n_batches) \
            * (n_batches - 1)
        total = form_first + dram + resid
        results[batch] = total
        # paper's selection criterion: "highest performance while
        # maintaining modest resource utilization" — Fig. 6 measures the
        # sorting fabric at ~3x LUT/FF per batch doubling (~N^1.585).
        lut_cost = batch ** 1.585
        efficiency[batch] = (base - total) / lut_cost
        emit(f"fig9/batch{batch}", us,
             f"form_cycles={form_first:.0f}|total_cycles={total:.0f}|"
             f"vs_unscheduled={1 - total / base:.1%}|"
             f"saving_per_lut={efficiency[batch]:.1f}")
    best_raw = min(results, key=results.get)
    best_eff = max(efficiency, key=efficiency.get)
    emit("fig9/optimum", 0.0,
         f"best_throughput_batch={best_raw}|"
         f"best_perf_per_resource={best_eff}|claim=32-64|"
         f"in_claimed_range={best_eff in (32, 64)}")


if __name__ == "__main__":
    run()
