"""Model-trace zoo benchmark — the paper's §V tuning question asked of
real model traffic instead of two synthetic shapes.

For every architecture in ``configs/registry.py`` the captured smoke
trace (``repro.data.model_traces``: embedding gathers, embedding-grad
scatters, KV appends, MoE expert dispatch, SSM state rewrites, frontend
streams) is

  1. replayed through the full ``MemoryController.simulate()`` pipeline
     under ``PAPER_COMBINED_CONFIG`` (multi-port: captured PE ids folded
     onto the 8 arbiter ports), and
  2. fed to ``autotune.tune(engine="batched")`` over the joint
     cache × channels × mapping × scheduler-batch × DRAM-sched/window
     grid,

answering whether *tuned controller geometry differs across model
families* (MoE vs dense vs SSM vs multimodal) the way the paper's GCN
differs from CNN. The verdict is machine-readable:
``geometry_differs_across_families`` compares the tuned geometry of each
family's representative architecture.

Writes ``BENCH_model_traces.json``; ``--small`` trims the tune grid for
the CI perf-smoke job (the trace set still covers all 10 architectures).
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import emit, write_bench_json
from repro.configs import registry
from repro.core import autotune
from repro.core.config import PAPER_COMBINED_CONFIG
from repro.core.controller import MemoryController
from repro.data import model_traces as mt

# Joint tune grid (full run). The batched engine scores the whole grid as
# one stacked computation, so the product stays cheap at zoo trace sizes.
FULL_GRID = dict(
    batch_sizes=(16, 64, 256),
    associativities=(1, 4),
    num_lines=(1024, 4096, 16384),
    dma_channels=(4,),
    num_channels=(1, 2, 4),
    mapping_policies=("row_interleave", "xor"),
    dram_sched_policies=("fifo", "frfcfs"),
    reorder_windows=(1, 16, 64),
)
SMALL_GRID = dict(
    batch_sizes=(16, 64),
    associativities=(1, 4),
    num_lines=(1024, 4096),
    dma_channels=(4,),
    num_channels=(1, 4),
    mapping_policies=("row_interleave", "xor"),
    dram_sched_policies=("fifo", "frfcfs"),
    reorder_windows=(1, 16),
)


def _geometry(cfg) -> dict:
    """The tuned controller geometry, flattened for comparison."""
    return {
        "sched_batch": cfg.scheduler.batch_size,
        "cache_ways": cfg.cache.associativity,
        "cache_lines": cfg.cache.num_lines,
        "num_channels": cfg.channels.num_channels,
        "mapping": cfg.channels.policy,
        "dram_sched": cfg.dram_sched.policy,
        "reorder_window": cfg.dram_sched.reorder_window,
        "dma_channels": cfg.dma.num_parallel_dma,
    }


def run(small: bool = False) -> dict:
    grid = SMALL_GRID if small else FULL_GRID
    base = PAPER_COMBINED_CONFIG
    results: dict = {
        "benchmark": "model_trace_zoo",
        "unit": "modeled_fpga_cycles",
        "row_bytes": mt.REPLAY_ROW_BYTES,
        "capture_shape": {"batch": mt.CAPTURE_BATCH, "seq": mt.CAPTURE_SEQ,
                          "decode_steps": mt.CAPTURE_DECODE_STEPS,
                          "seed": mt.TRACE_SEED},
        "grid": {k: list(v) for k, v in grid.items()},
        "configs": {},
        "families": {},
    }
    families = mt.arch_families()
    covered = 0
    for arch in registry.ARCH_IDS:
        fam = families[arch]
        t0 = time.perf_counter()
        try:
            cap = mt.cached_capture(arch)
            pe, rows, rw = cap.replay_arrays(base.num_pes)
            res = MemoryController(base).simulate(pe, rows, rw,
                                                  mt.REPLAY_ROW_BYTES)
            tr = autotune.tune(rows, mt.REPLAY_ROW_BYTES,
                               engine="batched", **grid)
        except Exception as e:  # a broken config must not hide the rest
            results["configs"][arch] = {"family": fam, "error": repr(e)}
            emit(f"perf_model_traces/{arch}", 0.0, f"ERROR {e!r}")
            continue
        covered += 1
        dt = (time.perf_counter() - t0) * 1e6
        geom = _geometry(tr.config)
        rec = {
            "family": fam,
            "trace": mt.summarize(cap),
            "simulate": {
                "config": "PAPER_COMBINED_CONFIG",
                "makespan_fpga_cycles": round(res.makespan_fpga_cycles),
                "dram_makespan_fpga_cycles": round(
                    res.dram_makespan_fpga_cycles),
                "cache_hit_rate": (None if res.cache_hit_rate is None
                                   else round(res.cache_hit_rate, 4)),
                "breakdown": {k: round(v, 1)
                              for k, v in res.breakdown().items()},
            },
            "tuned": {
                "modeled_cycles": round(tr.modeled_cycles, 1),
                "candidates_evaluated": tr.candidates_evaluated,
                "geometry": geom,
                "speedup_vs_paper_combined": round(
                    res.makespan_fpga_cycles / max(1.0, tr.modeled_cycles),
                    3),
            },
        }
        results["configs"][arch] = rec
        emit(f"perf_model_traces/{arch}", dt,
             f"family={fam}|n={len(cap)}|"
             f"makespan={rec['simulate']['makespan_fpga_cycles']}|"
             f"tuned={rec['tuned']['modeled_cycles']}|"
             f"geom={'/'.join(str(v) for v in geom.values())}")

    # Per-family verdict: the representative architecture's tuned geometry
    # (pinned-trace families), compared across families.
    geoms = {}
    for fam, arch in sorted(mt.FAMILY_REPRESENTATIVE.items()):
        rec = results["configs"].get(arch, {})
        if "tuned" not in rec:
            continue
        results["families"][fam] = {
            "representative": arch,
            "geometry": rec["tuned"]["geometry"],
            "tuned_cycles": rec["tuned"]["modeled_cycles"],
        }
        geoms[fam] = tuple(sorted(rec["tuned"]["geometry"].items()))
    differs = len(set(geoms.values())) >= 2
    results["geometry_differs_across_families"] = bool(differs)
    results["gate"] = {
        # gated in scripts/check_perf_regressions.py: both must hold at
        # --small size too (1/0 and a fraction, so the ratio floor works)
        "geometry_differs": int(differs),
        "configs_covered_frac": round(covered / len(registry.ARCH_IDS), 3),
    }
    results["n_configs_covered"] = covered
    emit("perf_model_traces/verdict", 0.0,
         f"covered={covered}/{len(registry.ARCH_IDS)}|"
         f"geometry_differs_across_families={differs}")
    write_bench_json("model_traces", results)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--small", action="store_true",
                    help="CI perf-smoke size (trimmed tune grid)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(small=args.small)


if __name__ == "__main__":
    main()
