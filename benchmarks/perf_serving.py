"""Open-loop serving benchmark — offered-load sweep to saturation plus
the hog-vs-victim tenant-isolation experiment (ARCHITECTURE §9).

Stage 1 measures the controller's *capacity* the honest way: the
closed-loop makespan of the trace (every request always waiting) gives
the peak service rate in requests per FPGA cycle. Stage 2 then offers
Poisson arrivals at fractions of that capacity and records the sojourn
distribution per arbiter policy: p50 stays near the unloaded service
time until the knee, p99 lifts first, and past saturation the sustained
rate pins at capacity while sojourns grow without bound — the classic
open-loop latency-throughput curve the closed-loop simulator cannot
express. The sweep itself runs through the batched
``autotune.sweep_serving_loads`` axis — one request-stream build for
all load points — with the one-at-a-time controller path timed
alongside and asserted bit-identical per point
(``batched_sweep`` in the JSON).

Stage 3 is the acceptance experiment (ISSUE 6), recorded
machine-readably as ``isolation.weighted_cap_protects_victim``: on a
two-tenant stream (sparse bursty SLO reads vs a saturating sequential
hog) the protected configuration — weighted arbitration favoring the
SLO tenant + FR-FCFS with a starvation cap — must give the victim a
strictly better modeled p99 sojourn than the unprotected reference
(round_robin + uncapped FR-FCFS) on the *same* arrival stream.

Writes ``BENCH_serving.json``; ``--small`` (~50k requests) is the CI
perf-smoke configuration.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from benchmarks.perf_pipeline import ROW_BYTES, gcn_style_trace
from repro.core.autotune import sweep_serving_loads
from repro.core.config import (CacheConfig, DRAMSchedConfig,
                               MemoryControllerConfig, SchedulerConfig)
from repro.core.controller import MemoryController
from repro.core.timing import (DDR4_2400, simulate_arrivals,
                               simulate_arrivals_seq)
from repro.data.synthetic import hog_victim_workload, poisson_arrivals

LOAD_FRACTIONS = (0.2, 0.5, 0.8, 0.95, 1.1, 1.4)
T_RFC, T_REFI = 420, 9363

BARE = MemoryControllerConfig(
    scheduler=SchedulerConfig(enabled=False),
    cache=CacheConfig(enabled=False))
SERVICE = DRAMSchedConfig(policy="frfcfs_cap", reorder_window=32,
                          starvation_cap=16, t_rfc=T_RFC, t_refi=T_REFI)


def _cfg(base: MemoryControllerConfig, sched: DRAMSchedConfig,
         **kw) -> MemoryControllerConfig:
    return dataclasses.replace(base, dram_sched=sched, **kw)


def _simulate(cfg, pe, rows, rw, *, arrival=None, policy="round_robin",
              weights=None, open_loop=None):
    mc = MemoryController(cfg)
    t0 = time.perf_counter()
    res = mc.simulate(pe, rows, rw, ROW_BYTES, arbiter_policy=policy,
                      weights=weights, arrival_cycle=arrival,
                      open_loop=open_loop)
    return res, (time.perf_counter() - t0) * 1e6


def run(n_requests: int = 200_000) -> dict:
    rng = np.random.default_rng(0)
    rows, rw = gcn_style_trace(rng, n_requests)
    cfg = _cfg(BARE, SERVICE)

    # ---- stage 1: capacity (closed loop — the saturated service rate)
    closed, dt = _simulate(cfg, None, rows, rw)
    capacity = n_requests / closed.makespan_fpga_cycles
    emit("perf_serving/capacity_closed_loop", dt,
         f"capacity={capacity:.5f}req_per_cycle|"
         f"makespan={round(closed.makespan_fpga_cycles)}")

    results: dict = {
        "benchmark": "open_loop_serving_sweep",
        "unit": "modeled_fpga_cycles",
        "n_requests": n_requests,
        "row_bytes": ROW_BYTES,
        "service": {"policy": SERVICE.policy,
                    "reorder_window": SERVICE.reorder_window,
                    "starvation_cap": SERVICE.starvation_cap,
                    "t_rfc": T_RFC, "t_refi": T_REFI},
        "capacity_req_per_cycle": capacity,
        "closed_loop_makespan": closed.makespan_fpga_cycles,
        "load_fractions": list(LOAD_FRACTIONS),
        "sweep": {},
    }

    # ---- stage 2: offered-load sweep to saturation --------------------
    # The sweep itself runs through the batched axis (one stream build,
    # many arrival vectors); the one-at-a-time controller path is timed
    # alongside on the same arrivals and must agree point for point.
    arrivals = [poisson_arrivals(np.random.default_rng(17), n_requests,
                                 capacity * frac)
                for frac in LOAD_FRACTIONS]
    refs, dts, t_oracle_sweep = [], [], 0.0
    for arr in arrivals:
        ref, dt = _simulate(cfg, None, rows, rw, arrival=arr)
        refs.append(ref)
        dts.append(dt)
        t_oracle_sweep += dt / 1e6
    t0 = time.perf_counter()
    swept = sweep_serving_loads(cfg, rows, rw, None, arrivals, ROW_BYTES)
    t_batched_sweep = time.perf_counter() - t0
    for frac, ref, dt, res in zip(LOAD_FRACTIONS, refs, dts, swept):
        s = res.serving
        assert (ref.makespan_fpga_cycles == res.makespan_fpga_cycles
                and ref.serving.p99_sojourn == s.p99_sojourn
                and ref.serving.sustained_req_per_cycle
                == s.sustained_req_per_cycle), \
            f"batched sweep diverged at load {frac}"
        rec = {
            "offered_req_per_cycle": s.offered_req_per_cycle,
            "sustained_req_per_cycle": s.sustained_req_per_cycle,
            "p50_sojourn": round(s.p50_sojourn, 1),
            "p95_sojourn": round(s.p95_sojourn, 1),
            "p99_sojourn": round(s.p99_sojourn, 1),
            "mean_sojourn": round(s.mean_sojourn, 1),
            "idle_fpga_cycles": round(s.idle_fpga_cycles, 1),
        }
        results["sweep"][f"{frac:.2f}"] = rec
        emit(f"perf_serving/sweep_load{frac:.2f}", dt,
             f"p50={rec['p50_sojourn']}|p99={rec['p99_sojourn']}|"
             f"sustained={s.sustained_req_per_cycle:.5f}")
    results["batched_sweep"] = {
        "load_points": len(LOAD_FRACTIONS),
        "one_at_a_time_s": round(t_oracle_sweep, 3),
        "batched_s": round(t_batched_sweep, 3),
        "speedup": round(t_oracle_sweep / t_batched_sweep, 2),
        "bit_identical": True,
        "note": ("open-loop serving is simulation-bound, so the "
                 "stacked axis buys the single-call API (one stream "
                 "build + validation for the whole sweep), not wall "
                 "time; expect ~1.0x here"),
    }
    emit("perf_serving/batched_sweep", t_batched_sweep * 1e6,
         f"speedup={t_oracle_sweep / t_batched_sweep:.2f}x|"
         f"points={len(LOAD_FRACTIONS)}")

    sweep = results["sweep"]
    lo, hi = sweep[f"{LOAD_FRACTIONS[0]:.2f}"], \
        sweep[f"{LOAD_FRACTIONS[-1]:.2f}"]
    # open-loop sanity: light load keeps p99 near the unloaded sojourn;
    # past saturation the sustained rate pins at capacity (±refresh
    # noise) while the tail blows up
    results["tail_blows_up_past_saturation"] = bool(
        hi["p99_sojourn"] > 10 * lo["p99_sojourn"])
    results["sustained_pins_at_capacity"] = bool(
        abs(hi["sustained_req_per_cycle"] - capacity) < 0.05 * capacity)
    knee = next((f for f in LOAD_FRACTIONS
                 if sweep[f"{f:.2f}"]["p99_sojourn"]
                 > 3 * lo["p99_sojourn"]), None)
    results["knee_load_fraction"] = knee

    # ---- stage 3: tenant isolation (the acceptance experiment) -------
    n_victim = max(200, n_requests // 10)
    n_hog = max(800, (4 * n_requests) // 10)
    protected = _cfg(BARE, SERVICE, num_pes=2)
    uncapped = _cfg(BARE, dataclasses.replace(SERVICE, policy="frfcfs"),
                    num_pes=2)
    rows2, rw2, pe2, arr2 = hog_victim_workload(
        np.random.default_rng(4), n_victim=n_victim, n_hog=n_hog,
        victim_rate=0.2 * capacity, hog_rate=1.2 * capacity)
    iso: dict = {"n_victim": n_victim, "n_hog": n_hog,
                 "victim_rate": 0.2 * capacity,
                 "hog_rate": 1.2 * capacity, "tenants": {}}
    for label, c, pol, w in (
            ("weighted_cap", protected, "weighted", (4, 1)),
            ("round_robin_uncapped", uncapped, "round_robin", None)):
        res, dt = _simulate(c, pe2, rows2, rw2, arrival=arr2,
                            policy=pol, weights=w)
        per = {str(p): rec for p, rec in res.serving.per_port.items()}
        iso["tenants"][label] = {
            "victim_p50": round(per["0"]["p50_sojourn"], 1),
            "victim_p99": round(per["0"]["p99_sojourn"], 1),
            "hog_p99": round(per["1"]["p99_sojourn"], 1),
            "makespan": round(res.makespan_fpga_cycles, 1),
        }
        emit(f"perf_serving/isolation_{label}", dt,
             f"victim_p99={iso['tenants'][label]['victim_p99']}|"
             f"hog_p99={iso['tenants'][label]['hog_p99']}")
    v_prot = iso["tenants"]["weighted_cap"]["victim_p99"]
    v_ref = iso["tenants"]["round_robin_uncapped"]["victim_p99"]
    iso["victim_p99_improvement"] = round(v_ref / v_prot, 3)
    iso["weighted_cap_protects_victim"] = bool(v_prot < v_ref)
    results["isolation"] = iso

    # ---- simulator throughput: fast path vs request-at-a-time oracle -
    n_perf = min(20_000, n_requests)
    addrs = rows[:n_perf] * ROW_BYTES
    arr_p = poisson_arrivals(np.random.default_rng(5), n_perf,
                             capacity * 0.9)
    t0 = time.perf_counter()
    oracle = simulate_arrivals_seq(addrs, DDR4_2400, SERVICE,
                                   rw=rw[:n_perf], arrival_fpga=arr_p)
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    fast = simulate_arrivals(addrs, DDR4_2400, SERVICE, rw=rw[:n_perf],
                             arrival_fpga=arr_p)
    t_fast = time.perf_counter() - t0
    assert fast.total_fpga_cycles == oracle.total_fpga_cycles
    results["simulator"] = {
        "n": n_perf,
        "oracle_s": round(t_seq, 3),
        "fast_s": round(t_fast, 3),
        "speedup": round(t_seq / t_fast, 1),
    }
    emit("perf_serving/simulator_fast_vs_oracle", t_fast * 1e6,
         f"speedup={t_seq / t_fast:.1f}x|n={n_perf}")

    write_bench_json("serving", results)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--small", action="store_true",
                    help="CI perf-smoke size (~50k requests)")
    ap.add_argument("--n", type=int, default=None,
                    help="override trace length")
    args = ap.parse_args()
    n = args.n or (50_000 if args.small else 200_000)
    print("name,us_per_call,derived")
    run(n)


if __name__ == "__main__":
    main()
