"""Tracing-overhead benchmark — what does observability cost?

The telemetry contract (ARCHITECTURE §11) has two performance claims
worth pinning machine-readably:

* **off is free** — ``trace=None`` touches no code on the hot path, so
  the untraced serving run must produce *bit-identical* results with
  the telemetry module merely importable (asserted here, not timed:
  bit-identity is the stronger statement); the off-path wall time is
  still recorded so a regression that sneaks work onto the hot path
  shows up as ``off_us`` drift in the perf trajectory;
* **on is bounded** — tracing-on reruns the identical workload with a
  :class:`~repro.core.telemetry.TraceRecorder` attached and records
  the slowdown factor and reconstructed events/second. The replay is
  O(events) python, so the factor is the price of the per-request
  lens — it should stay in single digits.

The workload is the open-loop multi-tenant serving shape (hog +
victim, weighted arbitration, FR-FCFS-cap with refresh) at 1M requests
full-size — large enough that both the timing run and the replay are
in steady state. Writes ``BENCH_telemetry.json``; ``--small`` (~20k
requests) is the CI perf-smoke configuration.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.core.config import (CacheConfig, DRAMSchedConfig,
                               MemoryControllerConfig, SchedulerConfig)
from repro.core.controller import MemoryController
from repro.core.telemetry import CycleAttribution, TraceRecorder
from repro.data.synthetic import hog_victim_workload

ROW_BYTES = 4096
SERVICE = DRAMSchedConfig(policy="frfcfs_cap", reorder_window=32,
                          starvation_cap=16, t_rfc=420, t_refi=9363)
CFG = MemoryControllerConfig(
    num_pes=2,
    scheduler=SchedulerConfig(enabled=False),
    cache=CacheConfig(enabled=False),
    dram_sched=SERVICE)


def _workload(n: int):
    n_victim = n // 5
    rows, rw, pe, arr = hog_victim_workload(
        np.random.default_rng(0), n_victim=n_victim,
        n_hog=n - n_victim, victim_rate=0.01, hog_rate=0.12)
    return pe, rows, rw, arr


def _simulate(pe, rows, rw, arr, trace=None):
    mc = MemoryController(CFG)
    t0 = time.perf_counter()
    res = mc.simulate(pe, rows, rw, ROW_BYTES, arbiter_policy="weighted",
                      weights=(4, 1), arrival_cycle=arr, trace=trace)
    return res, (time.perf_counter() - t0) * 1e6


def run(n_requests: int = 1_000_000) -> dict:
    pe, rows, rw, arr = _workload(n_requests)

    # tracing off — the hot path; timed twice, keep the better (the
    # first run also warms the allocator)
    res_off, dt_off = _simulate(pe, rows, rw, arr)
    res_off2, dt_off2 = _simulate(pe, rows, rw, arr)
    dt_off = min(dt_off, dt_off2)
    emit("perf_telemetry/tracing_off", dt_off,
         f"n={n_requests}|makespan={round(res_off.makespan_fpga_cycles)}")

    # tracing on — identical workload, recorder attached
    rec = TraceRecorder()
    res_on, dt_on = _simulate(pe, rows, rw, arr, trace=rec)

    # off-path overhead is *zero by construction*: the traced run must
    # reproduce every modeled number bit-for-bit
    identical = (
        res_off.makespan_fpga_cycles == res_on.makespan_fpga_cycles
        and np.array_equal(res_off.serving.completion_fpga_cycles,
                           res_on.serving.completion_fpga_cycles)
        and res_off2.makespan_fpga_cycles == res_off.makespan_fpga_cycles)
    assert identical, "tracing perturbed the model — contract violation"

    slowdown = dt_on / dt_off
    ev_per_s = rec.n_events / (dt_on * 1e-6)
    emit("perf_telemetry/tracing_on", dt_on,
         f"slowdown={slowdown:.2f}x|events={rec.n_events}|"
         f"events_per_s={ev_per_s:.0f}")

    t0 = time.perf_counter()
    att = CycleAttribution.from_pipeline(res_on, rec)
    dt_att = (time.perf_counter() - t0) * 1e6
    ident = bool(np.array_equal(att.ltr_sum(),
                                res_on.serving.sojourn_fpga_cycles))
    assert ident, "attribution exact-sum identity violated"
    emit("perf_telemetry/attribution", dt_att,
         f"exact_sum={ident}|components={len(att.components)}")

    results = {
        "n_requests": n_requests,
        "tracing_off_us": dt_off,
        "tracing_on_us": dt_on,
        "off_path_bit_identical": identical,
        "on_path_slowdown": slowdown,
        "n_events": int(rec.n_events),
        "events_per_second": ev_per_s,
        "attribution_us": dt_att,
        "attribution_exact_sum": ident,
        "makespan_fpga_cycles": float(res_off.makespan_fpga_cycles),
    }
    write_bench_json("telemetry", results)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--small", action="store_true",
                    help="CI perf-smoke size (~20k requests)")
    ap.add_argument("--n", type=int, default=None,
                    help="override trace length")
    args = ap.parse_args()
    n = args.n or (20_000 if args.small else 1_000_000)
    print("name,us_per_call,derived")
    run(n)


if __name__ == "__main__":
    main()
