"""Fig. 7 (write extension) — write-heavy workloads: scheduling on vs off.

The paper's Fig. 7 evaluates read-dominated GCN/CNN inference; this probe
applies the same methodology (cycle-level DDR4 simulation of the serviced
stream) to the write-heavy streams the ROADMAP targets:

  embedding_grad — training: the backward of an embedding lookup is a
        read-modify-write stream over Zipf-popular vocabulary rows (read
        the row, write the accumulated gradient). Unscheduled, the
        interleaved reads and writes pay a bus turnaround almost every
        request; the controller's dual-queue scheduler forms single-type
        batches and row-sorts each.

  kv_append — serving: B decoding sequences append one KV page per step
        while attention reads sweep their caches. Appends are sequential
        *per sequence* but the arrival stream interleaves sequences (and
        read sweeps), shredding row locality that batch-sorting restores.

Each workload reports modeled DRAM access time with the scheduler ON vs
OFF — same requests, same simulator; ordering plus the sorted batch's
VMEM write-coalescing are the only differences. (The MIG-like windowed
baseline is omitted here: it does not model bus turnaround, so it is not
comparable on write-heavy streams.)
"""

import time

import numpy as np

from benchmarks.common import emit
from repro.core import scheduler
from repro.core.config import PAPER_EVAL_CONFIG
from repro.core.scheduler import READ, WRITE
from repro.core.timing import DDR4_2400, simulate_dram_access


def embedding_grad_trace(rng, vocab=50_000, n_tokens=20_000,
                         row_bytes=4096, num_pes=8):
    """Read-modify-write per token over a Zipf vocabulary, issued by
    ``num_pes`` data-parallel workers whose streams interleave at the
    controller (the Fig. 7 multi-PE condition) — each worker's RMW pair
    is split apart by the others' traffic, so the unscheduled stream
    flips bus direction constantly and has no row locality."""
    tok = (rng.zipf(1.3, n_tokens) - 1) % vocab
    addrs = tok * row_bytes
    # Random async merge, vectorized: give every request a random arrival
    # key that is *sorted within its PE stream* (each worker issues in
    # order) and globally argsort — an arbitrary interleave of the
    # workers' RMW pairs with per-stream order preserved.
    per_a = [np.repeat(addrs[p::num_pes], 2) for p in range(num_pes)]
    per_rw = [np.tile(np.array([READ, WRITE], np.int32), a.shape[0] // 2)
              for a in per_a]
    keys = np.concatenate([np.sort(rng.random(a.shape[0])) for a in per_a])
    order = np.argsort(keys, kind="stable")
    return (np.concatenate(per_a)[order].astype(np.int64),
            np.concatenate(per_rw)[order])


def kv_append_trace(rng, batch=32, steps=256, page_bytes=2048,
                    reads_per_step=4):
    """Interleaved per-sequence appends + strided cache read sweeps."""
    seq_base = (np.arange(batch, dtype=np.int64) << 24)
    addrs, rw = [], []
    for t in range(steps):
        for b in range(batch):
            # read a few random earlier pages (attention), then append
            if t:
                pages = rng.integers(0, t, min(reads_per_step, t))
                for p in pages:
                    addrs.append(seq_base[b] + p * page_bytes)
                    rw.append(READ)
            addrs.append(seq_base[b] + t * page_bytes)
            rw.append(WRITE)
    return (np.asarray(addrs, np.int64),
            np.asarray(rw, np.int32))


def run_workload(name: str, addrs: np.ndarray, rw: np.ndarray) -> float:
    cfg = PAPER_EVAL_CONFIG
    t = DDR4_2400

    t0 = time.perf_counter()
    off = simulate_dram_access(addrs, t, rw=rw)
    # Same pipeline the controller API exposes (modeled_access_time with
    # coalesce_writes=True): typed batches → per-batch row sort → per-batch
    # VMEM write coalescing. Reads are left untouched (their dedup is the
    # cache engine's job, modeled in fig7).
    served, served_rw = scheduler.schedule_trace_rw(
        addrs, rw, config=cfg.scheduler, timings=t, coalesce_writes=True)
    on = simulate_dram_access(served, t, rw=served_rw)
    sim_us = (time.perf_counter() - t0) * 1e6

    improvement = 1 - on.total_fpga_cycles / off.total_fpga_cycles
    n_flips = int((rw[1:] != rw[:-1]).sum())
    n_flips_served = int((served_rw[1:] != served_rw[:-1]).sum())
    emit(f"fig7w/{name}", sim_us,
         f"improvement_sched_on_vs_off={improvement:.1%}|"
         f"on_cycles={on.total_fpga_cycles:.0f}|"
         f"off_cycles={off.total_fpga_cycles:.0f}|"
         f"writes_coalesced={addrs.shape[0] - served.shape[0]}|"
         f"row_hit_on={on.hit_rate:.2f}|row_hit_off={off.hit_rate:.2f}|"
         f"bus_turnarounds={n_flips}->{n_flips_served}")
    return {
        "improvement_sched_on_vs_off": round(improvement, 4),
        "on_cycles": round(on.total_fpga_cycles),
        "off_cycles": round(off.total_fpga_cycles),
        "writes_coalesced": int(addrs.shape[0] - served.shape[0]),
        "row_hit_rate_on": round(on.hit_rate, 4),
        "row_hit_rate_off": round(off.hit_rate, 4),
        "bus_turnarounds_before": n_flips,
        "bus_turnarounds_after": n_flips_served,
    }


def run() -> dict:
    """Returns per-workload modeled-improvement records; the runner
    persists them as BENCH_fig7_write.json."""
    rng = np.random.default_rng(0)
    eg = run_workload("embedding_grad", *embedding_grad_trace(rng))
    kv = run_workload("kv_append", *kv_append_trace(rng))
    return {"benchmark": "fig7_write_modeled_access_time",
            "workloads": {"embedding_grad": eg, "kv_append": kv}}


if __name__ == "__main__":
    run()
