"""Fig. 5 — DMA engine resource utilization vs buffer size / channel count.

URAM climbs linearly with simultaneous DMAs x buffer size; LUT/FF stays
<2%. TPU mapping: double-buffered VMEM staging per channel; 'logic' is the
constant kernel footprint. ``us_per_call`` times a 1 MiB bulk copy through
the engine at that configuration (oracle data plane; the Pallas kernel is
timed in its own tests in interpret mode).
"""

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core.config import DMAConfig
from repro.core.dma_engine import bulk_copy, channel_vmem_bytes, plan_transfer

VMEM_BYTES = 128 * 1024 * 1024


def run() -> None:
    src = jnp.arange(256 * 1024, dtype=jnp.float32)   # 1 MiB payload
    for buf_kb in (4, 16, 64):
        for ch in (1, 2, 4, 8):
            cfg = DMAConfig(buffer_bytes=buf_kb * 1024, num_parallel_dma=ch,
                            max_transaction_bytes=buf_kb * 1024)
            vmem_pct = 100 * channel_vmem_bytes(cfg) / VMEM_BYTES
            plan = plan_transfer(src.size * 4, cfg)
            fn = jax.jit(lambda s: bulk_copy(s, config=cfg))
            us = time_call(fn, src, iters=3, warmup=1)
            emit(f"fig5/buf{buf_kb}KB_ch{ch}", us,
                 f"vmem={vmem_pct:.3f}%|txns={plan.num_transactions}")


if __name__ == "__main__":
    run()
